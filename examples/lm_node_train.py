"""End-to-end driver: train a ~100M-param LM with the paper's technique.

The transformer stack is trained as a depth-time neural ODE whose gradients
come from the symplectic adjoint (NodeConfig).  With method="euler" the
forward map is exactly the discrete transformer, so this is the unmodified
architecture trained with O(L + one-layer) activation memory and EXACT
gradients — the paper's result applied at LM scale.  Checkpointing and
crash-resume run through the production runtime.

    # full ~100M run (a few hundred steps; slow on CPU):
    PYTHONPATH=src python examples/lm_node_train.py --preset full --steps 300
    # CI-sized run:
    PYTHONPATH=src python examples/lm_node_train.py --preset ci

``REPRO_BENCH_SMOKE=1`` forces the ci preset at a handful of steps.
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec, NodeConfig
from repro.data.tokens import TokenPipeline
from repro.optim import cosine_schedule
from repro.runtime import Checkpointer
from repro.train import TrainConfig, init_train_state, make_train_step

PRESETS = {
    # ~103M params: 10L x d640 x ffn2560, 32k vocab
    "full": dict(d_model=640, n_layers=10, n_heads=10, head_dim=64,
                 d_ff=2560, vocab=32768, seq=256, batch=8),
    "ci": dict(d_model=128, n_layers=4, n_heads=4, head_dim=32,
               d_ff=512, vocab=1024, seq=64, batch=8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ci", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-mode", default="symplectic")
    ap.add_argument("--node-method", default="euler")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    if os.environ.get("REPRO_BENCH_SMOKE"):
        args.preset = "ci"
        args.steps = min(args.steps, 3)
    ps = PRESETS[args.preset]

    arch = ArchConfig(
        name=f"lm-node-{args.preset}", family="dense",
        d_model=ps["d_model"], n_layers=ps["n_layers"],
        n_heads=ps["n_heads"], n_kv_heads=ps["n_heads"],
        head_dim=ps["head_dim"], d_ff=ps["d_ff"], vocab=ps["vocab"],
        pattern=(LayerSpec("attn", "dense"),), tie_embeddings=True,
        node=NodeConfig(mode="node", method=args.node_method,
                        grad_mode=args.grad_mode))
    tcfg = TrainConfig(lr=args.lr, loss_chunk=0)
    state = init_train_state(jax.random.PRNGKey(0), arch, tcfg)
    n_params = sum(int(l.size) for l in
                   jax.tree_util.tree_leaves(state["params"]))
    print(f"[lm_node] {arch.name}: {n_params/1e6:.1f}M params, "
          f"grad_mode={args.grad_mode} method={args.node_method}")

    sched = cosine_schedule(args.lr, warmup=10, total=args.steps)
    step_fn = jax.jit(make_train_step(arch, tcfg, lr_fn=sched),
                      donate_argnums=(0,))
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None

    pipe = iter(TokenPipeline(ps["batch"], ps["seq"], arch.vocab))
    t0 = time.time()
    tokens_seen = 0
    for step in range(args.steps):
        batch = next(pipe)
        state, metrics = step_fn(state, batch)
        tokens_seen += ps["batch"] * ps["seq"]
        if step % 10 == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"[lm_node] step {step:4d} "
                  f"loss {float(metrics['loss']):7.4f} "
                  f"gnorm {float(metrics['grad_norm']):6.3f} "
                  f"tok/s {tokens_seen/max(dt, 1e-9):9.0f} {dt:7.1f}s")
        if ckpt and (step + 1) % 50 == 0:
            ckpt.save(step + 1, state)
    print("[lm_node] done")


if __name__ == "__main__":
    main()
