"""Learn KdV dynamics with an HNN++ energy net (paper Sec. 5.2, reduced).

Eighth-order Dormand-Prince (13 stages) + symplectic adjoint: the setting
where per-stage checkpointing matters most.

    PYTHONPATH=src python examples/physics_kdv.py --system kdv --steps 150

``REPRO_BENCH_SMOKE=1`` shrinks everything to CI-smoke sizes (seconds).
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.data.physics_gen import generate_trajectories
from repro.models.physics import (PhysicsConfig, init_energy_net,
                                  physics_loss, rollout)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--system", default="kdv",
                    choices=["kdv", "cahn_hilliard"])
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--grad-mode", default="symplectic")
    ap.add_argument("--method", default="dopri8")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    if smoke:
        args.steps = min(args.steps, 3)
        args.method = "dopri5"   # 7 stages, not dopri8's 13

    cfg = PhysicsConfig(grid=32 if smoke else 64, system=args.system,
                        method=args.method, grad_mode=args.grad_mode,
                        n_steps=2 if smoke else 4)
    print(f"generating {args.system} trajectories...")
    trajs = generate_trajectories(args.system, n_traj=2 if smoke else 6,
                                  grid=cfg.grid,
                                  n_snapshots=9 if smoke else 16,
                                  substeps=20 if smoke else 80)
    u_k = jnp.asarray(trajs[:-1, :-1].reshape(-1, cfg.grid))
    u_k1 = jnp.asarray(trajs[:-1, 1:].reshape(-1, cfg.grid))
    params = init_energy_net(jax.random.PRNGKey(0), cfg)

    @jax.jit
    def step(params, a, b):
        mse, g = jax.value_and_grad(physics_loss)(params, a, b, cfg)
        params = jax.tree_util.tree_map(lambda x, y: x - args.lr * y,
                                        params, g)
        return params, mse

    t0 = time.time()
    bs = 32
    for i in range(args.steps):
        lo = (i * bs) % (u_k.shape[0] - bs)
        params, mse = step(params, u_k[lo:lo + bs], u_k1[lo:lo + bs])
        if i % 25 == 0 or i == args.steps - 1:
            print(f"[{args.system} {args.method} {args.grad_mode}] "
                  f"step {i:4d} one-step mse {float(mse):.6f} "
                  f"{time.time() - t0:6.1f}s")

    # long-term rollout on a held-out trajectory: ONE multi-observation
    # solve over [dt, 7*dt] instead of 7 chained single-interval solves
    u0 = jnp.asarray(trajs[-1, 0:1])
    preds = rollout(params, u0, cfg, horizon=7)
    errs = [float(jnp.mean((preds[j - 1] - trajs[-1, j]) ** 2))
            for j in range(1, 8)]
    print("rollout MSE per horizon:",
          " ".join(f"{e:.5f}" for e in errs))


if __name__ == "__main__":
    main()
