"""Quickstart: the symplectic adjoint method in ~80 lines.

Trains a tiny neural ODE on a 2-D spiral flow and shows the headline
property: the symplectic adjoint returns the same gradient as
backpropagation-through-the-solver (exact), while the classic continuous
adjoint does not — at a fraction of backprop's memory.  Then solves a
heterogeneous-stiffness batch with per-trajectory adaptive step control
(``solve(..., batch_axis=0)``, docs/batching.md).

    PYTHONPATH=src python examples/quickstart.py

Uses the composable API: ``solve(f, x0, params, gradient=<strategy>)``
returns a ``Solution`` whose ``.ys`` is differentiable (docs/api.md).
"""
import os

import jax
import jax.numpy as jnp

from repro.core import (AdaptiveConfig, ContinuousAdjoint, DirectBackprop,
                        SymplecticAdjoint, solve)

jax.config.update("jax_enable_x64", True)


def field(x, t, p):
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return h @ p["w2"]


def main():
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    params = {"w1": jax.random.normal(k1, (2, 32)) * 0.5,
              "b1": jnp.zeros(32),
              "w2": jax.random.normal(k2, (32, 2)) * 0.5}

    # target: rotate points by 90 degrees
    x0 = jax.random.normal(k3, (64 if smoke else 256, 2))
    target = x0 @ jnp.array([[0.0, 1.0], [-1.0, 0.0]])

    def loss(params, gradient):
        sol = solve(field, x0, params, method="dopri5", gradient=gradient,
                    stepping=8)
        return jnp.mean((sol.ys - target) ** 2)

    g_sym = jax.grad(loss)(params, SymplecticAdjoint())
    g_bp = jax.grad(loss)(params, DirectBackprop())
    g_adj = jax.grad(loss)(params, ContinuousAdjoint())

    def rel(a, b):
        na = jnp.sqrt(sum(jnp.sum((x - y) ** 2) for x, y in zip(
            jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))))
        nb = jnp.sqrt(sum(jnp.sum(x ** 2)
                          for x in jax.tree_util.tree_leaves(b)))
        return float(na / nb)

    print(f"|grad_symplectic - grad_backprop| / |grad_backprop| = "
          f"{rel(g_sym, g_bp):.2e}   <- exact (rounding only)")
    print(f"|grad_adjoint    - grad_backprop| / |grad_backprop| = "
          f"{rel(g_adj, g_bp):.2e}   <- discretization error")

    # train with the symplectic adjoint
    lr = 0.05
    p = params
    for step in range(20 if smoke else 200):
        l, g = jax.value_and_grad(loss)(p, SymplecticAdjoint())
        p = jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)
        if step % 50 == 0:
            print(f"step {step:4d}  loss {float(l):.5f}")
    print(f"final loss {float(loss(p, SymplecticAdjoint())):.5f}")

    # --- batch-native adaptive solving -----------------------------------
    # B independent oscillators, stiffness spread over a decade; axis 0 is
    # a batch of trajectories, each with its OWN adaptive controller.
    B = 4 if smoke else 8

    def osc(state, t, _p):
        x, om = state
        return (om[..., None] * jnp.stack([x[..., 1], -x[..., 0]], -1),
                jnp.zeros_like(om))

    x0 = (jnp.tile(jnp.array([1.0, 0.0]), (B, 1)), jnp.logspace(0., 1., B))
    sol = solve(osc, x0, {}, gradient=DirectBackprop(), batch_axis=0,
                stepping=AdaptiveConfig(rtol=1e-6, atol=1e-9, max_steps=256))
    print("batched solve, per-lane accepted steps:",
          sol.stats["n_steps"].tolist(), "(stiffer lane -> finer grid; "
          "a lockstep batch would force one shared grid)")


if __name__ == "__main__":
    main()
