"""Continuous normalizing flow on tabular data (paper Sec. 5.1, reduced).

Trains FFJORD-style CNFs with the adaptive dopri5 solver and the symplectic
adjoint — the paper's exact experimental recipe at laptop scale.

    PYTHONPATH=src python examples/cnf_tabular.py --dataset gas --steps 200

``REPRO_BENCH_SMOKE=1`` shrinks everything to CI-smoke sizes (seconds).
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.data.tabular import PAPER_DIMS, PAPER_M, make_tabular_dataset
from repro.models.cnf import CNFConfig, cnf_nll, init_cnf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="gas", choices=sorted(PAPER_DIMS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--grad-mode", default="symplectic")
    ap.add_argument("--adaptive", action="store_true",
                    help="dopri5 adaptive stepping (the paper's setting)")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    if os.environ.get("REPRO_BENCH_SMOKE"):
        args.steps = min(args.steps, 4)
        args.batch = min(args.batch, 32)

    cfg = CNFConfig(dim=PAPER_DIMS[args.dataset], hidden=(64, 64),
                    n_components=PAPER_M[args.dataset],
                    method="dopri5", grad_mode=args.grad_mode,
                    n_steps=8, adaptive=args.adaptive,
                    rtol=1e-4, atol=1e-6, max_steps=48)
    data = make_tabular_dataset(args.dataset, n=args.batch * 8)
    params = init_cnf(jax.random.PRNGKey(0), cfg)

    @jax.jit
    def step(params, u, eps):
        nll, g = jax.value_and_grad(cnf_nll)(params, u, eps, cfg)
        params = jax.tree_util.tree_map(lambda a, b: a - args.lr * b,
                                        params, g)
        return params, nll

    t0 = time.time()
    for i in range(args.steps):
        lo = (i * args.batch) % (7 * args.batch)
        u = jnp.asarray(data[lo:lo + args.batch])
        eps = jax.random.normal(jax.random.PRNGKey(i), u.shape)
        params, nll = step(params, u, eps)
        if i % 25 == 0 or i == args.steps - 1:
            print(f"[cnf:{args.dataset} M={cfg.n_components} "
                  f"{args.grad_mode}] step {i:4d} "
                  f"nll {float(nll):8.4f}  {time.time() - t0:6.1f}s")
    print("done")


if __name__ == "__main__":
    main()
