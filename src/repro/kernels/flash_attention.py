"""Pallas TPU kernel: flash attention (causal, GQA, sliding-window).

Online-softmax attention tiled for the TPU memory hierarchy:

  grid = (B, H, n_q_blocks, n_kv_blocks)   # kv innermost => sequential
  q tile   (block_q, D)  in VMEM, revisited across the kv dimension
  k/v tile (block_k, D)  in VMEM, GQA-mapped: kv head = h // (H // Hkv)
  scratch  m (block_q,1), l (block_q,1), acc (block_q, D) — float32 VMEM

The MXU consumes the (block_q, D) x (D, block_k) logit matmul and the
(block_q, block_k) x (block_k, D) value matmul; block sizes default to
128 so every matmul dimension is MXU-aligned.  Fully-masked tiles (beyond
the causal frontier or behind the sliding window) are skipped via pl.when,
giving the ~2x causal FLOP saving and the O(S*w) SWA cost that makes
mixtral's long_500k cell tractable.

Numerics follow the standard rescaling recurrence; -inf row-maxima (fully
masked rows, e.g. padding) are clamped so no NaN is produced.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  q_offset: int, kv_len: int, bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # --- tile-level skip tests (absolute positions) ----------------------
    q_lo = iq * bq + q_offset            # first absolute query position
    q_hi = q_lo + bq - 1
    k_lo = ik * bk
    k_hi = k_lo + bk - 1
    live = k_lo < kv_len
    if causal:
        live &= k_lo <= q_hi
    if window is not None:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, -jnp.inf)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
        corr = jnp.where(jnp.isfinite(m_prev),
                         jnp.exp(m_prev - m_safe), 0.0)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_offset", "scale",
                              "block_q", "block_k", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True,
                           window: Optional[int] = None,
                           q_offset: int = 0,
                           scale: Optional[float] = None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D). Returns (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    scale = scale if scale is not None else D ** -0.5

    bq = min(block_q, max(Sq, 1))
    bk = min(block_k, max(Sk, 1))
    sq_pad = -(-Sq // bq) * bq
    sk_pad = -(-Sk // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sk_pad - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sk_pad - Sk), (0, 0)))

    nq = sq_pad // bq
    nk = sk_pad // bk
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, kv_len=Sk, bq=bq, bk=bk, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, sq_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq, :]
