"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the interpret-mode kernel tests and the
default implementations used by the distributed dry-run (the CPU container
cannot lower Mosaic TPU kernels; see DESIGN.md §2).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def butcher_combine_ref(x: jnp.ndarray, ks: jnp.ndarray,
                        coefs: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """x + h * sum_i coefs[i] * ks[i].

    x: (...,), ks: (s, ...), coefs: (s,). The RK stage-combination hot loop
    (Eq. 5) fused into a single HBM pass.  Accumulates in
    promote_types(x.dtype, float32) — >= f32 for low-precision states, f64
    for f64 states — strictly in stage order: the exact dtype and sequence
    the Pallas kernel executes, so interpret-mode kernel runs match this
    oracle bit-for-bit.
    """
    acc_dt = jnp.promote_types(x.dtype, jnp.float32)
    hc = (h * coefs).astype(acc_dt)
    acc = x.astype(acc_dt)
    for i in range(ks.shape[0]):
        acc = acc + hc[i] * ks[i].astype(acc_dt)
    return acc.astype(x.dtype)


def butcher_combine_rows_ref(x: jnp.ndarray, ks: jnp.ndarray,
                             coefs: jnp.ndarray, base_scale: jnp.ndarray,
                             h: jnp.ndarray) -> jnp.ndarray:
    """Multi-row combine: out[r] = base_scale[r]*x + h*sum_i coefs[r,i]*ks[i].

    x: (...,), ks: (s, ...), coefs: (m, s), base_scale: (m,).  Returns
    (m,) + x.shape.  Same promote_types(x.dtype, f32) stage-order
    accumulation as the Pallas kernel (bit-for-bit in interpret mode).
    """
    acc_dt = jnp.promote_types(x.dtype, jnp.float32)
    hc = (h * coefs).astype(acc_dt)
    sc = base_scale.astype(acc_dt)
    xf = x.astype(acc_dt)
    outs = []
    for r in range(coefs.shape[0]):
        acc = sc[r] * xf
        for i in range(ks.shape[0]):
            acc = acc + hc[r, i] * ks[i].astype(acc_dt)
        outs.append(acc.astype(x.dtype))
    return jnp.stack(outs)


def rms_norm_ref(x: jnp.ndarray, weight: jnp.ndarray,
                 residual: Optional[jnp.ndarray] = None,
                 eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with optional fused residual add (pre-norm transformer).

    Returns normed output; if residual is given the normalization input is
    (x + residual) — the standard fused pre-norm pattern.
    """
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jnp.reciprocal(jnp.sqrt(var + eps))
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def _masked_softmax(s):
    m = jnp.max(s, axis=-1, keepdims=True)
    # rows that are fully masked (all -inf) produce zeros, not NaNs
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.where(jnp.isfinite(s), jnp.exp(s - m), 0.0)
    return e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, causal: bool = True,
                  window: Optional[int] = None,
                  q_offset: int = 0,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """Multi-head attention with GQA, causal masking and sliding window.

    q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D); H % Hkv == 0.
    ``q_offset`` is the absolute position of q[..., 0, :] (decode: Sk - Sq).
    window w: query j attends keys i with j - w < i <= j (SWA, mixtral-style).
    """
    B, H, Sq, D = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    Sk = k.shape[2]
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = _masked_softmax(s)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, pos,
                         *, window: Optional[int] = None,
                         scale: Optional[float] = None) -> jnp.ndarray:
    """Single-token GQA decode attention against a cache in ITS OWN dtype.

    q: (B, H, 1, D); k_cache, v_cache: (B, Smax, Hkv, D) (bf16 typically);
    pos: scalar int (absolute position of the new token).  No head repeat
    and no f32 copy of the cache — scores/output use f32 ACCUMULATION via
    preferred_element_type while the cache tensor stays bf16 (flash-
    decoding numerics).  Padding/future keys masked with kpos <= pos.
    """
    B, H, _, D = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(k_cache.shape[1])[None, None, None, :]
    mask = kpos <= pos
    if window is not None:
        mask &= kpos > pos - window
    s = jnp.where(mask, s, -jnp.inf)
    p = _masked_softmax(s)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, 1, D).astype(q.dtype)


def attention_blocked_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                          *, causal: bool = True,
                          window: Optional[int] = None,
                          q_offset: int = 0,
                          scale: Optional[float] = None,
                          block_q: int = 512) -> jnp.ndarray:
    """Query-blocked attention: identical math to attention_ref but never
    materializes the full (Sq, Sk) score matrix — peak live is
    (block_q, Sk) per (batch, head).  This is the long-sequence pure-JAX
    path used by the dry-run (the Pallas flash kernel is the TPU path);
    each block is rematerialized in backward (jax.checkpoint).
    """
    B, H, Sq, D = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, Sq)
    if Sq % bq != 0:
        return attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, scale=scale)
    kk = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    Sk = k.shape[2]
    kpos = jnp.arange(Sk)[None, :]
    nblocks = Sq // bq
    qb = q.reshape(B, H, nblocks, bq, D).transpose(2, 0, 1, 3, 4)

    @jax.checkpoint
    def one_block(args):
        qi, i = args
        s = jnp.einsum("bhqd,bhkd->bhqk", qi.astype(jnp.float32),
                       kk) * scale
        qpos = (i * bq + jnp.arange(bq))[:, None] + q_offset
        mask = jnp.ones((bq, Sk), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None], s, -jnp.inf)
        p = _masked_softmax(s)
        return jnp.einsum("bhqk,bhkd->bhqd", p, vv)

    def body(_, args):
        return None, one_block(args)

    _, ob = jax.lax.scan(body, None, (qb, jnp.arange(nblocks)))
    out = ob.transpose(1, 2, 0, 3, 4).reshape(B, H, Sq, D)
    return out.astype(q.dtype)

