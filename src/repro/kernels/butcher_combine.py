"""Pallas TPU kernels: fused Runge-Kutta stage combination.

Two variants over a stacked slope buffer ``ks`` with leading stage dim s:

  * ``butcher_combine_pallas``      — one coefficient ROW:
        out = x + h * sum_i coefs[i] * ks[i]
  * ``butcher_combine_rows_pallas`` — m rows of a Butcher matrix at once:
        out[r] = base_scale[r] * x + h * sum_i coefs[r, i] * ks[i]
    (one read of (x, ks) produces all m outputs — e.g. the step update and
    the embedded error estimate in a single pass, rows = [b; b_err] with
    base_scale = [1; 0]).

Why it matters for the paper: the RK update (Eq. 5) applies `s` AXPY chains
per step — with dopri5 that is up to 7 reads of the full state per stage
combination, repeated `N` times forward and ~3N times in the symplectic
backward pass.  The chain is purely memory-bound (arithmetic intensity
~ s FLOPs / (s+2) * 4 bytes < 1), so fusing it into one VMEM-tiled kernel
turns s+2 HBM passes into exactly one read of (x, ks) and one write of out.
The solver hot loop reaches these kernels through core/combine.py's
StageCombiner (``combine_backend="pallas"`` / "auto" on TPU).  Coefficient
rows may be traced values, not just tableau constants: the symplectic
backward recursion's h-dependent Eq. (7)/(8) rows and the SaveAt dense-
output Hermite rows (StageCombiner.interpolate, buffer [f_n, f_{n+1},
x_{n+1}-x_n]) both flow through the same single-row kernel.

Tiling: the state is reshaped to (rows, 128) lanes; each grid step processes
a (block_rows, 128) tile of x and the matching (s, block_rows, 128) tile of
ks — the (8, 128) float32 VREG layout and VMEM budget set block_rows.

Accumulation is ``promote_types(x.dtype, float32)`` (f32 for f32/bf16
states, f64 for f64 states under x64), strictly in stage order
i = 0..s-1 — the jnp oracles in ref.py use the identical dtype and order,
so interpret-mode kernel runs match the oracles bit-for-bit (asserted in
tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _pad_to_tiles(x, ks, block_rows):
    """Flatten x/(s,)+x to lane-tiled 2-D/3-D buffers, zero-padded."""
    s = ks.shape[0]
    n = x.size
    rows = -(-n // LANE)  # ceil
    rows_pad = -(-rows // block_rows) * block_rows
    pad = rows_pad * LANE - n
    xf = jnp.pad(x.reshape(-1), (0, pad)).reshape(rows_pad, LANE)
    kf = jnp.pad(ks.reshape(s, -1), ((0, 0), (0, pad))) \
        .reshape(s, rows_pad, LANE)
    return xf, kf, rows_pad, n


def _kernel(coef_ref, x_ref, ks_ref, o_ref, *, s: int, acc_dt):
    acc = x_ref[...].astype(acc_dt)
    for i in range(s):  # unrolled: s is a small static constant (<= 13)
        acc = acc + coef_ref[i].astype(acc_dt) * ks_ref[i].astype(acc_dt)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret"))
def butcher_combine_pallas(x: jnp.ndarray, ks: jnp.ndarray,
                           coefs: jnp.ndarray, h: jnp.ndarray,
                           *, block_rows: int = 256,
                           interpret: bool = True) -> jnp.ndarray:
    """x: (...,); ks: (s, ...); coefs: (s,); h: scalar."""
    s = ks.shape[0]
    orig_shape = x.shape
    xf, kf, rows_pad, n = _pad_to_tiles(x, ks, block_rows)
    acc_dt = jnp.promote_types(x.dtype, jnp.float32)
    hc = (h * coefs).astype(acc_dt)

    grid = (rows_pad // block_rows,)
    out = pl.pallas_call(
        functools.partial(_kernel, s=s, acc_dt=acc_dt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((s,), lambda r: (0,)),                 # coefs
            pl.BlockSpec((block_rows, LANE), lambda r: (r, 0)),  # x tile
            pl.BlockSpec((s, block_rows, LANE),
                         lambda r: (0, r, 0)),                   # ks tile
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, LANE), x.dtype),
        interpret=interpret,
    )(hc, xf, kf)
    return out.reshape(-1)[:n].reshape(orig_shape)


def _rows_kernel(coef_ref, scale_ref, x_ref, ks_ref, o_ref,
                 *, s: int, m: int, acc_dt):
    x = x_ref[...].astype(acc_dt)
    for r in range(m):  # unrolled: m is tiny (2 for update+error)
        acc = scale_ref[r].astype(acc_dt) * x
        for i in range(s):
            acc = acc + coef_ref[r, i].astype(acc_dt) * \
                ks_ref[i].astype(acc_dt)
        o_ref[r, :, :] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret"))
def butcher_combine_rows_pallas(x: jnp.ndarray, ks: jnp.ndarray,
                                coefs: jnp.ndarray, base_scale: jnp.ndarray,
                                h: jnp.ndarray, *, block_rows: int = 256,
                                interpret: bool = True) -> jnp.ndarray:
    """x: (...,); ks: (s, ...); coefs: (m, s); base_scale: (m,); h: scalar.

    Returns (m,) + x.shape; out[r] = base_scale[r]*x + h*sum_i coefs[r,i]*ks[i].
    """
    s = ks.shape[0]
    m = coefs.shape[0]
    orig_shape = x.shape
    xf, kf, rows_pad, n = _pad_to_tiles(x, ks, block_rows)
    acc_dt = jnp.promote_types(x.dtype, jnp.float32)
    hc = (h * coefs).astype(acc_dt)
    sc = base_scale.astype(acc_dt)

    grid = (rows_pad // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rows_kernel, s=s, m=m, acc_dt=acc_dt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, s), lambda r: (0, 0)),              # coefs
            pl.BlockSpec((m,), lambda r: (0,)),                  # base_scale
            pl.BlockSpec((block_rows, LANE), lambda r: (r, 0)),  # x tile
            pl.BlockSpec((s, block_rows, LANE),
                         lambda r: (0, r, 0)),                   # ks tile
        ],
        out_specs=pl.BlockSpec((m, block_rows, LANE), lambda r: (0, r, 0)),
        out_shape=jax.ShapeDtypeStruct((m, rows_pad, LANE), x.dtype),
        interpret=interpret,
    )(hc, sc, xf, kf)
    return out.reshape(m, -1)[:, :n].reshape((m,) + orig_shape)
