"""Pallas TPU kernel: fused residual-add + RMSNorm.

out = rmsnorm(x [+ residual]) * weight, computed in float32 in VMEM.

Pre-norm transformers evaluate this 2x per block x N steps x (1 fwd + 3 bwd
under the symplectic adjoint), and it is strictly memory-bound: fusing the
residual add saves one full HBM round-trip of the activation tensor.

Tiling: rows = all leading dims flattened; the feature dim d (multiple of
128 for every assigned architecture after padding) stays resident per tile,
so the mean-of-squares reduction happens entirely in VMEM/VREGs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel_nores(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _kernel_res(x_ref, res_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32) + res_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rms_norm_pallas(x: jnp.ndarray, weight: jnp.ndarray,
                    residual: Optional[jnp.ndarray] = None,
                    *, eps: float = 1e-6, block_rows: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    orig_shape = x.shape
    d = x.shape[-1]
    rows = x.size // d
    rows_pad = -(-rows // block_rows) * block_rows
    pad = rows_pad - rows

    def prep(a):
        return jnp.pad(a.reshape(rows, d), ((0, pad), (0, 0)))

    xf = prep(x)
    grid = (rows_pad // block_rows,)
    row_spec = pl.BlockSpec((block_rows, d), lambda r: (r, 0))
    w_spec = pl.BlockSpec((d,), lambda r: (0,))

    if residual is None:
        out = pl.pallas_call(
            functools.partial(_kernel_nores, eps=eps),
            grid=grid,
            in_specs=[row_spec, w_spec],
            out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct((rows_pad, d), x.dtype),
            interpret=interpret,
        )(xf, weight)
    else:
        out = pl.pallas_call(
            functools.partial(_kernel_res, eps=eps),
            grid=grid,
            in_specs=[row_spec, row_spec, w_spec],
            out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct((rows_pad, d), x.dtype),
            interpret=interpret,
        )(xf, prep(residual), weight)
    return out[:rows].reshape(orig_shape)
