"""Jit'd public wrappers for the Pallas kernels with oracle fallback.

``use_pallas``: None (auto) selects the Pallas path only on TPU backends;
the pure-jnp oracle otherwise (CPU dry-run / tests call the kernels
explicitly with interpret=True).  This keeps the 512-device dry-run lowering
free of Mosaic ops while the TPU deployment path hits the kernels.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .butcher_combine import (butcher_combine_pallas,
                              butcher_combine_rows_pallas)
from .flash_attention import flash_attention_pallas
from .rmsnorm import rms_norm_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(use_pallas: Optional[bool]) -> bool:
    return _on_tpu() if use_pallas is None else use_pallas


def butcher_combine(x, ks, coefs, h, *, use_pallas: Optional[bool] = None):
    if _resolve(use_pallas):
        return butcher_combine_pallas(x, ks, jnp.asarray(coefs),
                                      jnp.asarray(h),
                                      interpret=not _on_tpu())
    return ref.butcher_combine_ref(x, ks, jnp.asarray(coefs), jnp.asarray(h))


def butcher_combine_rows(x, ks, coefs, base_scale, h, *,
                         use_pallas: Optional[bool] = None):
    """Multi-row stage combine: (m,)+x.shape outputs from ONE read of (x, ks)."""
    if _resolve(use_pallas):
        return butcher_combine_rows_pallas(x, ks, jnp.asarray(coefs),
                                           jnp.asarray(base_scale),
                                           jnp.asarray(h),
                                           interpret=not _on_tpu())
    return ref.butcher_combine_rows_ref(x, ks, jnp.asarray(coefs),
                                        jnp.asarray(base_scale),
                                        jnp.asarray(h))


def rms_norm(x, weight, residual=None, *, eps: float = 1e-6,
             use_pallas: Optional[bool] = None):
    if _resolve(use_pallas):
        return rms_norm_pallas(x, weight, residual, eps=eps,
                               interpret=not _on_tpu())
    return ref.rms_norm_ref(x, weight, residual, eps=eps)


def attention(q, k, v, *, causal: bool = True,
              window: Optional[int] = None, q_offset: int = 0,
              scale: Optional[float] = None,
              use_pallas: Optional[bool] = None):
    if _resolve(use_pallas):
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      q_offset=q_offset, scale=scale,
                                      interpret=not _on_tpu())
    Sq, Sk = q.shape[2], k.shape[2]
    if Sq * Sk > 2048 * 4096 and Sq >= 1024:
        # long-sequence path: query-blocked, never materializes (Sq, Sk)
        return ref.attention_blocked_ref(q, k, v, causal=causal,
                                         window=window, q_offset=q_offset,
                                         scale=scale)
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, scale=scale)
