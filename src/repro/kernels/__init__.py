"""Pallas TPU kernels for the perf-critical hot spots, with jnp oracles.

  butcher_combine — fused RK stage combination (the paper's Eq. 5 hot loop)
  rms_norm        — fused residual + RMSNorm
  attention       — flash attention (causal, GQA, sliding window, decode)

Each kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd wrapper
with TPU/oracle dispatch), ref.py (pure-jnp oracle).
"""
from .ops import attention, butcher_combine, rms_norm

__all__ = ["attention", "butcher_combine", "rms_norm"]
