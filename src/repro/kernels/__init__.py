"""Pallas TPU kernels for the perf-critical hot spots, with jnp oracles.

  butcher_combine      — fused RK stage combination (the paper's Eq. 5 hot
                         loop): one coefficient row against a stacked
                         (s, rows, 128) slope buffer in a single HBM pass
  butcher_combine_rows — multi-row variant: m rows of a Butcher matrix
                         (e.g. [b; b_err] with base scales [1; 0]) from ONE
                         read of (x, ks) — fuses the step update with the
                         embedded error estimate
  rms_norm             — fused residual + RMSNorm
  attention            — flash attention (causal, GQA, sliding window, decode)

Each kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd wrapper
with TPU/oracle dispatch), ref.py (pure-jnp oracle).

The butcher_combine kernels are the solver hot path: core/combine.py's
StageCombiner routes every RK stage linear combination — forward stage
states, the step update, the embedded error, and the symplectic-adjoint
backward Lambda/lambda recursions — through them whenever
``combine_backend`` resolves to "pallas" (the default on TPU backends).
The oracles accumulate in float32 in the same stage order as the kernels,
so interpret-mode runs match the oracles bit-for-bit.
"""
from .ops import attention, butcher_combine, butcher_combine_rows, rms_norm

__all__ = ["attention", "butcher_combine", "butcher_combine_rows",
           "rms_norm"]
