import os
import sys

if "--analysis" not in sys.argv:
    # 512 virtual devices for the production-mesh compile cells.  The
    # --analysis mode never builds a mesh — it only traces jaxprs — and
    # must not pay the 512-device backend startup cost.
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single-pod or 2x16x16
multi-pod), abstract-initializes the full train/serve state with
jax.eval_shape (no allocation), attaches the parallel/shardings.py
PartitionSpecs, and runs jax.jit(...).lower(...).compile().  Success proves
the sharding config is coherent; the compiled artifact yields
memory_analysis (fits-per-chip proof) and cost_analysis + collective bytes
(the §Roofline inputs).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k [--multipod] [--out runs/dryrun.jsonl] \
        [--node-mode] [--ep] [--all]

``--analysis`` switches to a compile-free mode: the repro.analysis static
auditor traces every gradient strategy under the integrators the named
configs use (NODE depth stack, CNF) and prints the per-strategy Table-1
memory table — answers "which grad_mode fits?" without executing a solve:

    PYTHONPATH=src python -m repro.launch.dryrun --analysis \
        [--analysis-config node,cnf] [--out runs/analysis.jsonl]
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_arch
from repro.configs.base import ArchConfig, NodeConfig, ShapeConfig
from repro.configs.registry import ARCH_IDS, cell_is_applicable
from repro.launch.analysis import (bf16_upcast_bytes, collective_bytes,
                                   count_params, hbm_headroom,
                                   model_flops_per_step, roofline_terms)
from repro.launch.mesh import make_production_mesh
from repro.models.encdec import init_encdec_caches
from repro.models.lm import init_caches
from repro.parallel import (batch_specs, cache_specs, make_sharder,
                            param_specs, state_specs)
from repro.train import (TrainConfig, init_train_state, make_decode_step,
                         make_prefill_step, make_train_step)

SDS = jax.ShapeDtypeStruct
I32 = jnp.int32
N_VLM_PATCHES = 256


# ---------------------------------------------------------------------------
# abstract inputs per cell
# ---------------------------------------------------------------------------

def train_input_specs(arch: ArchConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if arch.encdec:
        return {"frames": SDS((B, S, arch.d_frontend), jnp.bfloat16),
                "tokens": SDS((B, S), I32), "labels": SDS((B, S), I32)}
    if arch.frontend == "patch":
        St = S - N_VLM_PATCHES
        return {"patch_embeds": SDS((B, N_VLM_PATCHES, arch.d_frontend),
                                    jnp.bfloat16),
                "tokens": SDS((B, St), I32), "labels": SDS((B, St), I32)}
    return {"tokens": SDS((B, S), I32), "labels": SDS((B, S), I32)}


def abstract_state(arch: ArchConfig, tcfg: TrainConfig):
    return jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), arch, tcfg))


def abstract_caches(arch: ArchConfig, batch: int, max_len: int):
    if arch.encdec:
        return jax.eval_shape(
            lambda: init_encdec_caches(arch, batch, max_len, max_len))
    return jax.eval_shape(
        lambda: init_caches(arch, batch, max_len))


def _sh(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------

MAMBA_PARAM_NAMES = frozenset({"in_proj", "out_proj", "x_proj",
                               "dt_proj", "conv_w", "conv_b"})


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
             node_mode: bool = False, ep: bool = False,
             seq_shard_train: Optional[str] = None,
             param_dtype: str = "bfloat16",
             correction: bool = True,
             replicate_mamba: bool = False,
             verbose: bool = True) -> dict:
    shape = SHAPES[shape_name]
    arch = get_arch(arch_id).with_(use_pallas=False)
    if node_mode:
        arch = arch.with_(node=NodeConfig(mode="node", method="euler",
                                          grad_mode="symplectic"))
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    tcfg = TrainConfig(param_dtype=param_dtype)

    overrides = {}
    if seq_shard_train:
        overrides["seq"] = seq_shard_train
    shard = make_sharder(mesh, overrides=overrides)

    t0 = time.time()
    state_abs = abstract_state(arch, tcfg)
    # FSDP for training of >8B-param models: TP alone cannot hold params +
    # optimizer + transients in 16 GB/chip (production default at this
    # scale).  Serving stays TP-only (per-layer all-gathers would add
    # decode latency; params-only fit fine).
    n_params_est = count_params(state_abs["params"])
    fsdp = shape.kind == "train" and n_params_est > 8e9
    # gradient accumulation: bound per-microbatch activation / MoE-capacity
    # buffers.  >8B models by default; jamba's 8-layer unit and xlstm's
    # recurrent transients need it too (see EXPERIMENTS.md §Perf Cell A).
    MB = {"jamba-v0.1-52b": 8, "xlstm-1.3b": 4}
    mb = MB.get(arch_id, 4 if n_params_est > 8e9 else 1)
    if shape.kind == "train" and mb > 1 and tcfg.microbatches == 1:
        tcfg = TrainConfig(param_dtype=param_dtype, microbatches=mb)
    sspecs = state_specs(state_abs, mesh, fsdp=fsdp)
    state_sh = _sh(mesh, sspecs)
    result = {"arch": arch_id, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "n_chips": n_chips, "kind": shape.kind,
              "node_mode": node_mode, "ep": ep, "fsdp": fsdp,
              "microbatches": tcfg.microbatches,
              "replicate_mamba": replicate_mamba}

    with mesh:
        if shape.kind == "train":
            batch_abs = train_input_specs(arch, shape)
            batch_sh = _sh(mesh, batch_specs(batch_abs, mesh))
            # ZeRO-2-style gradient sharding hook (see make_train_step)
            gsh = _sh(mesh, sspecs["opt"]["m"])

            def grad_constraint(grads):
                return jax.tree_util.tree_map(
                    jax.lax.with_sharding_constraint, grads, gsh)

            step = make_train_step(arch, tcfg, shard=shard,
                                   grad_constraint=grad_constraint)
            metric_sh = {k: NamedSharding(mesh, P())
                         for k in ("loss", "grad_norm", "lr")}
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, metric_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_abs, batch_abs)
            n_tokens = shape.global_batch * shape.seq_len
        elif shape.kind == "prefill":
            B, S = shape.global_batch, shape.seq_len
            params_abs = state_abs["params"]
            extra = MAMBA_PARAM_NAMES if replicate_mamba else frozenset()
            params_sh = _sh(mesh, param_specs(params_abs, mesh, ep=ep,
                                              extra_replicated=extra))
            batch_abs = train_input_specs(arch, shape)
            batch_abs.pop("labels")
            batch_sh = _sh(mesh, batch_specs(batch_abs, mesh))
            caches_abs = abstract_caches(arch, B, S)
            caches_sh = _sh(mesh, cache_specs(caches_abs, mesh,
                                              batch_size=B))

            def cache_constraint(c):
                return jax.tree_util.tree_map(
                    jax.lax.with_sharding_constraint, c, caches_sh)

            prefill = make_prefill_step(arch, B, S, shard=shard,
                                        cache_constraint=cache_constraint)
            dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            dp_size = int(np.prod([mesh.shape[a] for a in dp]))
            logits_sh = NamedSharding(mesh, P(
                dp if B % dp_size == 0 else None, None,
                "model" if arch.vocab % mesh.shape["model"] == 0
                else None))
            jitted = jax.jit(prefill, in_shardings=(params_sh, batch_sh),
                             out_shardings=(logits_sh, caches_sh))
            lowered = jitted.lower(params_abs, batch_abs)
            n_tokens = B * S
        else:  # decode
            B, S = shape.global_batch, shape.seq_len
            params_abs = state_abs["params"]
            params_sh = _sh(mesh, param_specs(params_abs, mesh, ep=ep))
            caches_abs = abstract_caches(arch, B, S)
            caches_sh = _sh(mesh, cache_specs(caches_abs, mesh,
                                              batch_size=B))
            tok_abs = SDS((B, 1), I32)
            tok_sh = _sh(mesh, batch_specs({"t": tok_abs}, mesh))["t"]
            pos_sh = NamedSharding(mesh, P())
            decode = make_decode_step(arch, shard=shard)
            jitted = jax.jit(decode,
                             in_shardings=(params_sh, caches_sh, tok_sh,
                                           pos_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, caches_abs, tok_abs,
                                   SDS((), I32))
            n_tokens = B  # one new token per sequence
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    coll_total = sum(coll.values())
    upcast = bf16_upcast_bytes(hlo_text)

    flops_raw = float(cost.get("flops", 0.0))
    bytes_raw = float(cost.get("bytes accessed", 0.0))

    # trip-count correction for scanned layer stacks (see docstring)
    try:
        corr = unit_flops_correction(arch, shape, mesh, state_abs, shard,
                                     shape.kind) if correction \
            else dict(ZERO_COST)
    except Exception as e:  # noqa: BLE001
        corr = dict(ZERO_COST)
        result["correction_error"] = f"{type(e).__name__}: {e}"
    flops_dev = flops_raw + corr["flops"]
    bytes_dev = bytes_raw + corr["bytes"]
    coll_total_corr = coll_total + corr["coll"]
    terms = roofline_terms(flops_dev, bytes_dev, coll_total_corr)

    n_params = count_params(state_abs["params"])
    n_active = active_params(arch, n_params)
    mf = model_flops_per_step(n_active, n_tokens, shape.kind)
    hlo_flops_global = flops_dev * n_chips

    result.update({
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "n_params": n_params, "n_active_params": n_active,
        "n_tokens": n_tokens,
        "bytes_per_device": {
            "arguments": mem.argument_size_in_bytes,
            "outputs": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "aliased": mem.alias_size_in_bytes,
            "code": mem.generated_code_size_in_bytes,
        },
        "peak_hbm_gb": round((mem.argument_size_in_bytes
                              + mem.output_size_in_bytes
                              + mem.temp_size_in_bytes
                              - mem.alias_size_in_bytes) / 2**30, 3),
        "cpu_bf16_upcast_gb": round(upcast / 2**30, 3),
        "peak_hbm_gb_tpu": round((mem.argument_size_in_bytes
                                  + mem.output_size_in_bytes
                                  + mem.temp_size_in_bytes
                                  - mem.alias_size_in_bytes
                                  - upcast) / 2**30, 3),
        "hbm_headroom": hbm_headroom(mem.argument_size_in_bytes
                                     + mem.output_size_in_bytes
                                     + mem.temp_size_in_bytes
                                     - mem.alias_size_in_bytes
                                     - upcast),
        "flops_per_device": flops_dev,
        "flops_per_device_raw": flops_raw,
        "bytes_accessed_per_device": bytes_dev,
        "collective_bytes_per_device": coll,
        "collective_total_bytes": coll_total_corr,
        "roofline": terms,
        "model_flops_global": mf,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": round(mf / hlo_flops_global, 4)
        if hlo_flops_global else None,
    })
    if verbose:
        print(json.dumps(result, indent=None, default=str))
    return result


def _cost_of(jitted, *args) -> dict:
    compiled = jitted.lower(*args).compile()
    cost = compiled.cost_analysis() or {}
    coll = sum(collective_bytes(compiled.as_text()).values())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll)}


def _cost_add(a, b, scale=1.0):
    return {k: a[k] + scale * b[k] for k in a}


ZERO_COST = {"flops": 0.0, "bytes": 0.0, "coll": 0.0}


def unit_flops_correction(arch: ArchConfig, shape: ShapeConfig, mesh,
                          state_abs, shard, kind: str) -> float:
    """XLA cost_analysis counts a while-loop body ONCE regardless of trip
    count, so scanned layer stacks are undercounted by ~R.  Measure the
    repeated unit's own compiled FLOPs on the same mesh (fwd for serving;
    fwd + fwd&bwd for training, matching the remat schedule: fwd-scan body
    (1x fwd) + bwd-scan body (remat fwd + bwd = 3x fwd-equiv)) and return
    the missing (R-1) * body FLOPs.  Stays measured-from-compiled-HLO.
    """
    from repro.models.lm import _unit_forward
    from repro.models import encdec as ed
    from repro.nn.norm import rmsnorm
    from repro.nn.mlp import swiglu

    B, S = shape.global_batch, shape.seq_len
    dtype = jnp.bfloat16
    Sq = 1 if kind == "decode" else S
    pos = jnp.asarray(S - 1, jnp.int32) if kind == "decode" else None

    def slice0(tree):
        return jax.tree_util.tree_map(
            lambda l: SDS(l.shape[1:], l.dtype), tree)

    def measure(body, R, *arg_specs):
        """body(*args) -> activation tree; returns (R-1) * body costs."""
        if R <= 1:
            return dict(ZERO_COST)
        with mesh:
            if kind == "train":
                vg = jax.jit(jax.value_and_grad(
                    lambda *a: jnp.sum(body(*a).astype(jnp.float32)),
                    argnums=(0, 1)))
                f = _cost_of(vg, *arg_specs)
                f = _cost_add(f, _cost_of(jax.jit(body), *arg_specs))
            else:
                f = _cost_of(jax.jit(body), *arg_specs)
        return {k: (R - 1) * v for k, v in f.items()}

    x_spec = SDS((B, Sq, arch.d_model), dtype)

    if not arch.encdec:
        unit_abs = state_abs["params"]["unit"]
        if kind == "train":
            def body(up, x):
                out, _, aux = _unit_forward(up, x, arch, shard=shard)
                return out + 0.0 * aux
            return measure(body, arch.n_repeats, slice0(unit_abs), x_spec)
        caches_abs = slice0(abstract_caches(arch, B, S)["unit"])

        def body(up, x, c):
            out, _, _ = _unit_forward(up, x, arch, caches=c, pos=pos,
                                      shard=shard)
            return out
        return measure(body, arch.n_repeats, slice0(unit_abs), x_spec,
                       caches_abs)

    # ---- enc-dec ---------------------------------------------------------
    total = dict(ZERO_COST)
    mem_spec = SDS((B, S, arch.d_model), dtype)

    def enc_body(up, x):
        h = rmsnorm(up["attn_norm"], x, eps=arch.norm_eps)
        y, _ = ed._mha(up["attn"], h, arch, causal=False, shard=shard)
        x = x + y
        h = rmsnorm(up["ffn_norm"], x, eps=arch.norm_eps)
        return x + swiglu(up["mlp"], h, shard=shard)

    def dec_body(up, x, mem, c):
        self_c = None if c is None else c["self"]
        cross_c = None if c is None else c["cross"]
        h = rmsnorm(up["self_norm"], x, eps=arch.norm_eps)
        y, _ = ed._mha(up["self_attn"], h, arch, causal=True, pos=pos,
                       cache=self_c, shard=shard)
        x = x + y
        h = rmsnorm(up["cross_norm"], x, eps=arch.norm_eps)
        y, _ = ed._mha(up["cross_attn"], h, arch, kv=mem, causal=False,
                       cache=cross_c, shard=shard)
        x = x + y
        h = rmsnorm(up["ffn_norm"], x, eps=arch.norm_eps)
        return x + swiglu(up["mlp"], h, shard=shard)

    enc_abs = slice0(state_abs["params"]["enc_unit"])
    dec_abs = slice0(state_abs["params"]["dec_unit"])
    if kind == "train":
        total = _cost_add(total, measure(
            enc_body, arch.enc_layers, enc_abs,
            SDS((B, S, arch.d_model), dtype)))
        total = _cost_add(total, measure(
            lambda up, x: dec_body(up, x, jnp.zeros(mem_spec.shape, dtype),
                                   None),
            arch.n_layers, dec_abs, x_spec))
        return total
    if kind == "prefill":
        # prefill compiles encoder (scan, R=enc_layers) + decoder prefill
        total = _cost_add(total, measure(
            enc_body, arch.enc_layers, enc_abs,
            SDS((B, S, arch.d_model), dtype)))
        caches_abs = slice0(jax.eval_shape(
            lambda: init_encdec_caches(arch, B, S, S)))
        total = _cost_add(total, measure(
            lambda up, x, c: dec_body(up, x,
                                      jnp.zeros(mem_spec.shape, dtype), c),
            arch.n_layers, dec_abs, x_spec, caches_abs))
        return total
    caches_abs = slice0(jax.eval_shape(
        lambda: init_encdec_caches(arch, B, S, S)))
    total = _cost_add(total, measure(
        lambda up, x, c: dec_body(up, x, None, c),
        arch.n_layers, dec_abs, x_spec, caches_abs))
    return total


def active_params(arch: ArchConfig, n_params: int) -> int:
    """Active (per-token) parameter count for MoE archs."""
    if arch.moe_experts == 0:
        return n_params
    # subtract inactive expert weights
    moe = arch.moe_config()
    per_expert = 3 * moe.d_ff * moe.d_model
    n_moe_layers = sum(1 for s in (list(arch.prefix)
                                   + list(arch.pattern) * arch.n_repeats)
                       if s.ffn == "moe")
    inactive = n_moe_layers * (moe.n_experts - moe.top_k) * per_expert
    return n_params - inactive


# ---------------------------------------------------------------------------
# static analysis mode (--analysis): no mesh, no compile, no solve
# ---------------------------------------------------------------------------

ANALYSIS_CONFIGS = ("node", "cnf")


def run_static_analysis(targets=ANALYSIS_CONFIGS, out=None,
                        verbose: bool = True) -> list:
    """Per-strategy memory audit of the named model configs, statically.

    For each named config this reads off the integrator it actually uses
    (configs/base.py NodeConfig for the NODE depth stack, models/cnf.py
    CNFConfig for the CNF likelihood solves), then asks ``repro.analysis``
    for the Table-1 memory table of EVERY registered gradient strategy
    under that integrator: reverse-mode jaxprs are traced at N and 8N
    fixed steps and walked with the define-to-last-use liveness
    accounting.  Nothing is compiled or executed — this answers "which
    grad_mode can this config afford?" in seconds on the login node.
    """
    from repro.analysis.memory import (memory_findings, memory_rows,
                                       memory_table_markdown)
    from repro.models.cnf import CNFConfig

    methods = {}
    if "node" in targets:
        methods.setdefault(NodeConfig().method, []).append("node")
    if "cnf" in targets:
        methods.setdefault(CNFConfig(dim=4).method, []).append("cnf")
    if not methods:
        raise SystemExit(f"--analysis: no known config in {targets!r}; "
                         f"have {ANALYSIS_CONFIGS}")

    rows = memory_rows(methods=tuple(sorted(methods)))
    findings = memory_findings(rows)
    results = []
    for r in rows:
        head = hbm_headroom(r.peak_big)
        results.append({"mode": "static_analysis",
                        "configs": methods[r.method],
                        "strategy": r.strategy, "method": r.method,
                        "peak_bytes_small": r.peak_small,
                        "peak_bytes_big": r.peak_big,
                        "n_small": r.n_small, "n_big": r.n_big,
                        "growth": round(r.growth, 3), **head})
    if verbose:
        used = ", ".join(f"{m} <- {'+'.join(cs)}"
                         for m, cs in sorted(methods.items()))
        print(f"static per-strategy memory audit (integrators: {used})")
        print(memory_table_markdown(rows))
        for f in findings:
            print(str(f))
    if out:
        with open(out, "a") as fh:
            for res in results:
                fh.write(json.dumps(res) + "\n")
    if findings:
        print(f"FAILED: {len(findings)} memory-bound findings",
              file=sys.stderr)
        sys.exit(1)
    print("static analysis OK")
    return results


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def iter_cells():
    for arch_id in ARCH_IDS:
        for shape_name in SHAPES:
            if cell_is_applicable(arch_id, shape_name):
                yield arch_id, shape_name


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch x shape) cell")
    ap.add_argument("--node-mode", action="store_true")
    ap.add_argument("--ep", action="store_true")
    ap.add_argument("--seq-shard", default=None)
    ap.add_argument("--replicate-mamba", action="store_true",
                    help="serve cells: replicate mamba weights (no TP "
                         "all-reduce per mamba layer)")
    ap.add_argument("--no-correction", action="store_true",
                    help="skip the trip-count cost correction (faster; "
                         "use for the multipod shardability pass)")
    ap.add_argument("--analysis", action="store_true",
                    help="static per-strategy memory audit (repro.analysis)"
                         " of the named configs — no mesh, no compile")
    ap.add_argument("--analysis-config", default=",".join(ANALYSIS_CONFIGS),
                    help="comma list of configs for --analysis: node, cnf")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args(argv)

    if args.analysis:
        run_static_analysis(
            tuple(t for t in args.analysis_config.split(",") if t),
            out=args.out)
        return

    cells = list(iter_cells()) if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multipod]
    failures = []
    for arch_id, shape_name in cells:
        for mp in meshes:
            try:
                res = run_cell(arch_id, shape_name, multi_pod=mp,
                               node_mode=args.node_mode, ep=args.ep,
                               seq_shard_train=args.seq_shard,
                               correction=not args.no_correction,
                               replicate_mamba=args.replicate_mamba)
            except Exception as e:  # noqa: BLE001
                res = {"arch": arch_id, "shape": shape_name,
                       "mesh": "2x16x16" if mp else "16x16",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                failures.append((arch_id, shape_name, mp))
                print(json.dumps({k: res[k] for k in
                                  ("arch", "shape", "mesh", "error")}))
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(res, default=str) + "\n")
    if failures:
        print(f"FAILED cells: {failures}", file=sys.stderr)
        sys.exit(1)
    print("dry-run OK")


if __name__ == "__main__":
    main()
