"""Compiled-artifact analysis: collective-byte parsing + roofline terms.

Hardware model (TPU v5e target):
    peak bf16 compute   197 TFLOP/s / chip
    HBM bandwidth       819 GB/s   / chip
    ICI link bandwidth  ~50 GB/s   / link

Roofline terms (seconds, per step, per chip — the dry-run compiles the
per-device SPMD module so cost_analysis is already per-chip):
    compute    = HLO_FLOPs / peak_flops
    memory     = HLO_bytes / hbm_bw
    collective = collective_bytes / link_bw
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_PER_CHIP = 16 * 2**30  # v5e: 16 GiB


def hbm_headroom(peak_bytes: float) -> Dict[str, float]:
    """Per-chip HBM fit for a peak-residency estimate.

    Works on either source of truth: ``compiled.memory_analysis()`` sums
    from a dry-run compile, or the static liveness peaks from
    ``repro.analysis`` (dryrun ``--analysis`` mode, no compile at all).
    """
    frac = peak_bytes / HBM_PER_CHIP
    return {"hbm_fraction": round(frac, 6), "fits": frac <= 1.0}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one 'dtype[dims]' shape string."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op in an HLO module.

    Counts `op(...)` and `op-start(...)` (async) forms once; `-done` ops are
    skipped.  Tuple shapes `(f32[..], f32[..])` sum their components.
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?\S+\s*=\s*(\(?[^=]*?\)?)\s+"
                     r"([a-z0-9\-]+)\(", line)
        if not m:
            continue
        shape_part, op = m.groups()
        base = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                base = c
                break
        if base is None:
            continue
        total = 0
        for sm in _SHAPE_RE.finditer(shape_part):
            total += _shape_bytes(sm.group(0))
        out[base] += total
    return out


_UPCAST_RE = re.compile(
    r"\(param[^:]*: bf16\[([0-9,]+)\]\) -> f32\[\1\]")


def bf16_upcast_bytes(hlo_text: str, min_bytes: int = 1 << 27) -> int:
    """Bytes of whole-tensor bf16->f32 convert fusions (>=128 MB each).

    The CPU backend lowers bf16 dots by converting operands to f32; when a
    scanned layer stack feeds such dots, the converts get hoisted into
    full-stack f32 copies.  TPU's MXU consumes bf16 natively, so these
    buffers DO NOT EXIST on the target hardware — we measure them here and
    report both the raw CPU number and the TPU-corrected peak.
    """
    total = 0
    for m in _UPCAST_RE.finditer(hlo_text):
        n = 1
        for d in m.group(1).split(","):
            n *= int(d)
        if n * 4 >= min_bytes:
            total += n * 4
    return total


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: float) -> Dict[str, float]:
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": coll_bytes / LINK_BW,
    }
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    total = max(terms["compute_s"], terms["memory_s"],
                terms["collective_s"])
    terms["roofline_fraction"] = (terms["compute_s"] / total) \
        if total > 0 else 0.0
    return terms


def model_flops_per_step(n_active_params: float, tokens: float,
                         kind: str) -> float:
    """6ND for training, 2ND for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens


def count_params(tree) -> int:
    import jax
    return sum(int(l.size) for l in jax.tree_util.tree_leaves(tree)
               if hasattr(l, "size"))
