"""Serving drivers: LM token decoding and the continuous-batching ODE engine.

    # batched LM serving: prefill a request batch, then decode tokens
    PYTHONPATH=src python -m repro.launch.serve lm --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 32 --gen-len 16

    # ODE solve serving: heterogeneous request stream through repro.serve
    PYTHONPATH=src python -m repro.launch.serve ode --smoke

The bare legacy form (no subcommand) still routes to ``lm``:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke
"""
from __future__ import annotations

import argparse
import sys
import time


def _lm_main(argv=None):
    ap = argparse.ArgumentParser(prog="repro.launch.serve lm")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None,
                    help="serve the params from a TRAINING checkpoint "
                    "(the full TrainState saved by repro.launch.train; "
                    "pass the same --grad-mode/--node-method the training "
                    "run used so the param pytree structures match)")
    ap.add_argument("--grad-mode", default=None)
    ap.add_argument("--node-method", default="euler")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch, get_smoke_arch
    from repro.configs.base import NodeConfig
    from repro.data.tokens import synthetic_lm_batch
    from repro.train import (TrainConfig, init_train_state,
                             make_decode_step, make_prefill_step)

    arch = get_smoke_arch(args.arch) if args.smoke else get_arch(args.arch)
    if args.grad_mode:
        arch = arch.with_(node=NodeConfig(mode="node",
                                          method=args.node_method,
                                          grad_mode=args.grad_mode))
    state = init_train_state(jax.random.PRNGKey(0), arch, TrainConfig())
    if args.ckpt_dir:
        # train -> serve handoff: the fresh state is only the restore
        # template (same arch => same pytree structure), every param is
        # overwritten with the trained values
        from repro.runtime import Checkpointer
        state, ck_step = Checkpointer(args.ckpt_dir).restore(state)
        print(f"[serve] restored params from {args.ckpt_dir} "
              f"step {ck_step}")
    params = state["params"]

    max_len = args.prompt_len + args.gen_len
    prefill = jax.jit(make_prefill_step(arch, args.batch, max_len))
    decode = jax.jit(make_decode_step(arch))

    b = synthetic_lm_batch(0, args.batch, args.prompt_len + 1, arch.vocab)
    batch = {"tokens": jnp.asarray(b["tokens"])}
    if arch.encdec:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len,
                                    arch.d_frontend))
    if arch.frontend == "patch":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, 4, arch.d_frontend))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    offset = 4 if arch.frontend == "patch" else 0
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    key = jax.random.PRNGKey(0)
    t0 = time.time()
    for i in range(args.gen_len - 1):
        pos = jnp.int32(args.prompt_len + offset + i)
        logits, caches = decode(params, caches, tok, pos)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"[serve] arch={arch.name} batch={args.batch} "
          f"prefill {args.prompt_len} tok in {t_prefill*1e3:.1f} ms; "
          f"decode {args.gen_len} tok in {t_decode*1e3:.1f} ms "
          f"({t_decode/max(args.gen_len-1,1)*1e3:.1f} ms/tok)")
    print("[serve] sample generation (token ids):", gen[0][:16].tolist())


def _ode_main(argv=None):
    ap = argparse.ArgumentParser(prog="repro.launch.serve ode")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes: rot-check that the engine runs")
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--method", default="dopri5")
    ap.add_argument("--buckets", type=int, nargs="+", default=[4, 8, 16])
    ap.add_argument("--max-steps", type=int, default=512)
    ap.add_argument("--rate", type=float, default=None,
                    help="offered load in requests/s (Poisson arrivals); "
                    "default: submit everything up front and drain")
    ap.add_argument("--naive", action="store_true",
                    help="also run the sequential single-solve baseline")
    args = ap.parse_args(argv)
    if args.smoke:
        args.dim, args.hidden = 4, 8
        args.requests = min(args.requests, 8)
        args.buckets = [2, 4]

    import jax
    import jax.numpy as jnp

    from repro.core import AdaptiveConfig
    from repro.core.tableau import get_tableau
    from repro.serve import (EngineConfig, SolveEngine, latency_summary,
                             naive_sequential_solve, poisson_arrivals,
                             serve_timed, synthetic_stream)

    dim, hidden = args.dim, args.hidden
    k = jax.random.split(jax.random.PRNGKey(args.seed + 17), 4)
    params = {"w1": jax.random.normal(k[0], (dim, hidden)) * 0.4,
              "b1": jax.random.normal(k[1], (hidden,)) * 0.1,
              "w2": jax.random.normal(k[2], (hidden, dim)) * 0.4,
              "b2": jax.random.normal(k[3], (dim,)) * 0.1}

    def field(x, t, p):
        return jnp.tanh(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]

    cfg = AdaptiveConfig(rtol=1e-4, atol=1e-6, max_steps=args.max_steps,
                         initial_step=0.02)
    reqs = synthetic_stream(args.requests, dim, seed=args.seed)

    t0 = time.perf_counter()
    engine = SolveEngine(field, get_tableau(args.method), cfg, params,
                         x0_template=jnp.zeros((dim,)),
                         engine_cfg=EngineConfig(buckets=tuple(args.buckets)))
    t_init = time.perf_counter() - t0
    print(f"[serve ode] engine up in {t_init:.2f}s "
          f"(AOT advance for buckets {tuple(args.buckets)})")

    arrivals = None
    if args.rate is not None:
        arrivals = poisson_arrivals(args.requests, args.rate, seed=args.seed)
    t0 = time.perf_counter()
    results = serve_timed(engine, reqs, arrivals)
    wall = time.perf_counter() - t0
    ok = sum(r.succeeded for r in results.values())
    lat = latency_summary(results)
    print(f"[serve ode] {len(results)} requests ({ok} ok) in {wall:.2f}s "
          f"-> {len(results)/wall:.1f} req/s"
          + (f" at offered {args.rate:.1f} req/s" if args.rate else
             " (drain mode)"))
    print(f"[serve ode] latency p50 {lat['p50_ms']:.1f} ms, "
          f"p99 {lat['p99_ms']:.1f} ms; engine stats {engine.stats}")

    if args.naive:
        _, lats = naive_sequential_solve(field, get_tableau(args.method),
                                         cfg, params, reqs)
        import numpy as np
        wall_n = float(np.sum(lats))       # steady state: warmup excluded
        print(f"[serve ode] naive sequential: {len(reqs)} requests in "
              f"{wall_n:.2f}s -> {len(reqs)/wall_n:.1f} req/s; per-solve "
              f"p50 {np.percentile(lats, 50)*1e3:.1f} ms")


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] in ("lm", "ode"):
        return {"lm": _lm_main, "ode": _ode_main}[argv[0]](argv[1:])
    # legacy spelling: no subcommand = the original LM driver flags
    return _lm_main(argv)


if __name__ == "__main__":
    main()
