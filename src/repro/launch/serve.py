"""Batched serving driver: prefill a request batch, then decode tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 32 --gen-len 16
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch, get_smoke_arch
    from repro.data.tokens import synthetic_lm_batch
    from repro.train import (TrainConfig, init_train_state,
                             make_decode_step, make_prefill_step)

    arch = get_smoke_arch(args.arch) if args.smoke else get_arch(args.arch)
    state = init_train_state(jax.random.PRNGKey(0), arch, TrainConfig())
    params = state["params"]

    max_len = args.prompt_len + args.gen_len
    prefill = jax.jit(make_prefill_step(arch, args.batch, max_len))
    decode = jax.jit(make_decode_step(arch))

    b = synthetic_lm_batch(0, args.batch, args.prompt_len + 1, arch.vocab)
    batch = {"tokens": jnp.asarray(b["tokens"])}
    if arch.encdec:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len,
                                    arch.d_frontend))
    if arch.frontend == "patch":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, 4, arch.d_frontend))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    offset = 4 if arch.frontend == "patch" else 0
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    key = jax.random.PRNGKey(0)
    t0 = time.time()
    for i in range(args.gen_len - 1):
        pos = jnp.int32(args.prompt_len + offset + i)
        logits, caches = decode(params, caches, tok, pos)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"[serve] arch={arch.name} batch={args.batch} "
          f"prefill {args.prompt_len} tok in {t_prefill*1e3:.1f} ms; "
          f"decode {args.gen_len} tok in {t_decode*1e3:.1f} ms "
          f"({t_decode/max(args.gen_len-1,1)*1e3:.1f} ms/tok)")
    print("[serve] sample generation (token ids):", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
