"""Distributed training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b-smoke \
        --steps 50 --ckpt-dir runs/ckpt --ckpt-every 10 [--resume]

On boot: restores from the newest valid checkpoint if present (crash /
preemption recovery); the data pipeline is keyed by step so the token
stream resumes exactly.  Runs on whatever devices exist — a 1-CPU test, a
256-chip pod, or the 512-chip multi-pod mesh (``--mesh``), resharding the
checkpoint onto the current topology (elastic restart).

Real-TPU deployment flags (latency-hiding scheduler for collective/compute
overlap, async collectives) are appended to XLA_FLAGS when --tpu-flags is
passed; they are no-ops on CPU.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

TPU_FLAGS = (
    " --xla_tpu_enable_data_parallel_all_reduce_opt=true"
    " --xla_tpu_data_parallel_opt_different_sized_ops=true"
    " --xla_enable_async_collective_permute=true"
    " --xla_tpu_overlap_compute_collective_tc=true"
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "constant"])
    ap.add_argument("--grad-mode", default=None,
                    help="node-mode gradient scheme (symplectic/...)")
    ap.add_argument("--node-method", default="euler")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "pod", "multipod", "debug"])
    ap.add_argument("--tpu-flags", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="inject a failure (fault-tolerance demo)")
    args = ap.parse_args(argv)

    if args.tpu_flags:
        os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + TPU_FLAGS

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch, get_smoke_arch
    from repro.configs.base import NodeConfig
    from repro.data.tokens import TokenPipeline
    from repro.launch.mesh import make_debug_mesh, make_production_mesh
    from repro.optim import (CompressionConfig, cosine_schedule,
                             constant_schedule, wsd_schedule)
    from repro.parallel import make_sharder, state_specs
    from repro.runtime import Checkpointer, RetryConfig, run_with_retries
    from repro.train import TrainConfig, init_train_state, make_train_step
    from jax.sharding import NamedSharding

    arch = get_smoke_arch(args.arch) if args.smoke else get_arch(args.arch)
    if args.grad_mode:
        arch = arch.with_(node=NodeConfig(mode="node",
                                          method=args.node_method,
                                          grad_mode=args.grad_mode))
    tcfg = TrainConfig(lr=args.lr, microbatches=args.microbatches,
                       compression=CompressionConfig(mode=args.compression))

    mesh = None
    if args.mesh == "pod":
        mesh = make_production_mesh(multi_pod=False)
    elif args.mesh == "multipod":
        mesh = make_production_mesh(multi_pod=True)
    elif args.mesh == "debug":
        mesh = make_debug_mesh()
    shard = make_sharder(mesh)

    sched = {"cosine": lambda: cosine_schedule(args.lr, 5, args.steps),
             "wsd": lambda: wsd_schedule(args.lr, 5,
                                         int(args.steps * 0.7),
                                         int(args.steps * 0.25)),
             "constant": lambda: constant_schedule(args.lr)}[args.schedule]()

    state = init_train_state(jax.random.PRNGKey(0), arch, tcfg)
    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir, keep=3, async_save=True)
        latest = ckpt.latest_step()
        if latest is not None:
            shardings = None
            if mesh is not None:
                specs = state_specs(state, mesh)
                shardings = jax.tree_util.tree_map(
                    lambda s: NamedSharding(mesh, s), specs,
                    is_leaf=lambda x: isinstance(
                        x, jax.sharding.PartitionSpec))
            state, start_step = ckpt.restore(state, shardings=shardings)
            print(f"[train] resumed from step {start_step}")

    step_fn = make_train_step(arch, tcfg, lr_fn=sched, shard=shard)
    if mesh is not None:
        specs = state_specs(state, mesh)
        state_sh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        step_fn = jax.jit(step_fn, in_shardings=(state_sh, None),
                          out_shardings=(state_sh, None),
                          donate_argnums=(0,))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    pipe = iter(TokenPipeline(args.global_batch, args.seq_len, arch.vocab,
                              start_step=start_step))

    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = next(pipe)
        if arch.encdec:
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.global_batch, args.seq_len,
                                           arch.d_frontend))
        if arch.frontend == "patch":
            batch["patch_embeds"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.global_batch, 4,
                                           arch.d_frontend))

        def do_step():
            if step == args.fail_at_step:
                args.fail_at_step = -1   # fail once
                raise RuntimeError("injected failure (demo)")
            return step_fn(state, batch)

        def on_failure():
            print(f"[train] step {step} failed; state intact, retrying")

        state, metrics = run_with_retries(do_step, RetryConfig(),
                                          on_failure)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {float(metrics['loss']):.4f}"
                  f" gnorm {float(metrics['grad_norm']):.3f}"
                  f" lr {float(metrics['lr']):.2e}"
                  f" {time.time() - t0:.1f}s")
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state, block=False)
    if ckpt is not None:
        ckpt.save(args.steps, state)
        ckpt.wait()
    print("[train] done")


if __name__ == "__main__":
    main()
