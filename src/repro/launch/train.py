"""Distributed training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --epochs 3 --steps-per-epoch 20 --ckpt-dir runs/ckpt \
        --ckpt-every 10 --metrics-out runs/metrics.jsonl [--resume]

The full train state — ``train.TrainState``: (params, AdamW state incl. the
LR-schedule step, RNG key, data cursor, solver stats, compression error
feedback) — is checkpointed as ONE pytree via ``runtime.Checkpointer`` with
async saves overlapping the train step.  On boot the driver restores from
the newest valid checkpoint if present (crash / preemption recovery);
``--resume`` makes that mandatory (exit 3 when no checkpoint exists).  The
data pipeline is keyed by step, so the token stream resumes exactly: the
fault-injection harness (tests/test_failures.py) SIGKILLs this driver
mid-epoch — including mid async save — and asserts the resumed
loss/grad-norm trajectory is BIT-identical to an uninterrupted run.

``--metrics-out`` appends one JSON line per step (flushed, so a killed run
leaves a complete prefix) — the harness and the CI train-smoke lane diff
these files across kill/resume boundaries (tools/check_resume_divergence.py).

Runs on whatever devices exist — a 1-CPU test, a 256-chip pod, or the
512-chip multi-pod mesh (``--mesh``), resharding the checkpoint onto the
current topology (elastic restart).  Real-TPU deployment flags
(latency-hiding scheduler for collective/compute overlap, async
collectives) are appended to XLA_FLAGS when --tpu-flags is passed; they are
no-ops on CPU.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

TPU_FLAGS = (
    " --xla_tpu_enable_data_parallel_all_reduce_opt=true"
    " --xla_tpu_data_parallel_opt_different_sized_ops=true"
    " --xla_enable_async_collective_permute=true"
    " --xla_tpu_overlap_compute_collective_tc=true"
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=20,
                    help="total steps (ignored when --steps-per-epoch is "
                    "given)")
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--steps-per-epoch", type=int, default=None,
                    help="with --epochs: total = epochs * steps_per_epoch")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "constant"])
    ap.add_argument("--grad-mode", default=None,
                    help="node-mode gradient scheme (symplectic/...)")
    ap.add_argument("--node-method", default="euler")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true",
                    help="REQUIRE a valid checkpoint in --ckpt-dir and "
                    "boot from it (without this flag a present checkpoint "
                    "is still used, but an empty dir starts fresh)")
    ap.add_argument("--metrics-out", default=None,
                    help="append one JSON line per step (step/epoch/loss/"
                    "grad_norm/lr), flushed — for resume-divergence checks")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "pod", "multipod", "debug"])
    ap.add_argument("--tpu-flags", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="inject a failure (fault-tolerance demo)")
    ap.add_argument("--step-delay-s", type=float, default=0.0,
                    help="sleep after each step — paces the loop so the "
                    "fault harness can SIGKILL mid-epoch deterministically")
    args = ap.parse_args(argv)

    if args.tpu_flags:
        os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + TPU_FLAGS

    import jax

    from repro.configs import get_arch, get_smoke_arch
    from repro.configs.base import NodeConfig
    from repro.data.tokens import TokenPipeline
    from repro.launch.mesh import make_debug_mesh, make_production_mesh
    from repro.optim import (CompressionConfig, cosine_schedule,
                             constant_schedule, wsd_schedule)
    from repro.parallel import make_sharder, state_specs
    from repro.runtime import Checkpointer, RetryConfig, mesh_shardings, \
        run_with_retries
    from repro.train import TrainConfig, init_train_state, make_train_step

    arch = get_smoke_arch(args.arch) if args.smoke else get_arch(args.arch)
    if args.grad_mode:
        arch = arch.with_(node=NodeConfig(mode="node",
                                          method=args.node_method,
                                          grad_mode=args.grad_mode))
    tcfg = TrainConfig(lr=args.lr, microbatches=args.microbatches,
                       compression=CompressionConfig(mode=args.compression))

    if args.steps_per_epoch is not None:
        total_steps = args.epochs * args.steps_per_epoch
        steps_per_epoch = args.steps_per_epoch
    else:
        total_steps = args.steps
        steps_per_epoch = max(1, (args.steps + args.epochs - 1)
                              // args.epochs)

    mesh = None
    if args.mesh == "pod":
        mesh = make_production_mesh(multi_pod=False)
    elif args.mesh == "multipod":
        mesh = make_production_mesh(multi_pod=True)
    elif args.mesh == "debug":
        mesh = make_debug_mesh()
    shard = make_sharder(mesh)

    sched = {"cosine": lambda: cosine_schedule(args.lr, 5, total_steps),
             "wsd": lambda: wsd_schedule(args.lr, 5,
                                         int(total_steps * 0.7),
                                         int(total_steps * 0.25)),
             "constant": lambda: constant_schedule(args.lr)}[args.schedule]()

    state = init_train_state(jax.random.PRNGKey(0), arch, tcfg)
    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir, keep=3, async_save=True)
        latest = ckpt.latest_step()
        if latest is None and args.resume:
            print(f"[train] --resume: no valid checkpoint in "
                  f"{args.ckpt_dir}", file=sys.stderr)
            sys.exit(3)
        if latest is not None:
            shardings = None
            if mesh is not None:
                shardings = mesh_shardings(mesh, state_specs(state, mesh))
            state, start_step = ckpt.restore(state, shardings=shardings)
            # the data cursor IS the checkpoint step: the pipeline resumes
            # the exact sample stream
            assert int(state["data_step"]) == start_step, \
                (int(state["data_step"]), start_step)
            print(f"[train] resumed from step {start_step} "
                  f"(epoch {start_step // steps_per_epoch})")
    elif args.resume:
        print("[train] --resume requires --ckpt-dir", file=sys.stderr)
        sys.exit(3)

    step_fn = make_train_step(arch, tcfg, lr_fn=sched, shard=shard)
    if mesh is not None:
        state_sh = mesh_shardings(mesh, state_specs(state, mesh))
        step_fn = jax.jit(step_fn, in_shardings=(state_sh, None),
                          out_shardings=(state_sh, None),
                          donate_argnums=(0,))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    pipe = iter(TokenPipeline(args.global_batch, args.seq_len, arch.vocab,
                              start_step=start_step))
    metrics_f = None
    if args.metrics_out:
        out_dir = os.path.dirname(args.metrics_out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        metrics_f = open(args.metrics_out, "a")

    t0 = time.time()
    epoch_losses = []
    for step in range(start_step, total_steps):
        batch = next(pipe)
        if arch.encdec:
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.global_batch, args.seq_len,
                                           arch.d_frontend))
        if arch.frontend == "patch":
            batch["patch_embeds"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.global_batch, 4,
                                           arch.d_frontend))

        def do_step():
            if step == args.fail_at_step:
                args.fail_at_step = -1   # fail once
                raise RuntimeError("injected failure (demo)")
            return step_fn(state, batch)

        def on_failure():
            print(f"[train] step {step} failed; state intact, retrying")

        state, metrics = run_with_retries(do_step, RetryConfig(),
                                          on_failure)
        epoch = step // steps_per_epoch
        loss = float(metrics["loss"])
        epoch_losses.append(loss)
        if metrics_f is not None:
            # json round-trips python floats exactly (repr-based), so the
            # resume-divergence check compares bit-identical values
            metrics_f.write(json.dumps(
                {"step": step, "epoch": epoch, "loss": loss,
                 "grad_norm": float(metrics["grad_norm"]),
                 "lr": float(metrics["lr"])}) + "\n")
            metrics_f.flush()
        if step % 5 == 0 or step == total_steps - 1:
            print(f"[train] step {step:5d} loss {loss:.4f}"
                  f" gnorm {float(metrics['grad_norm']):.3f}"
                  f" lr {float(metrics['lr']):.2e}"
                  f" {time.time() - t0:.1f}s")
        if (step + 1) % steps_per_epoch == 0:
            print(f"[train] epoch {epoch} done: mean loss "
                  f"{sum(epoch_losses) / len(epoch_losses):.4f} "
                  f"({len(epoch_losses)} steps)")
            epoch_losses = []
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            # async: the host transfer is the only stall; the file write
            # overlaps the next step (bench_checkpoint measures both)
            ckpt.save(step + 1, state, block=False)
        if args.step_delay_s:
            time.sleep(args.step_delay_s)
    if ckpt is not None:
        ckpt.save(total_steps, state)
        ckpt.wait()
    if metrics_f is not None:
        metrics_f.close()
    sstats = jax.tree_util.tree_map(int, state["solver_stats"])
    print(f"[train] done (solver stats {sstats})")


if __name__ == "__main__":
    main()
