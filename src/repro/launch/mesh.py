"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
initialization.
"""
from __future__ import annotations

import math

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    jax.sharding.AxisType) only exist in newer releases; Auto is the
    default there, so omitting the kwarg on older jax is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
    TP ("model") stays inside a pod; DP spans ("pod", "data").
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small host-device mesh for CI tests.

    Checks the device count eagerly: ``jax.make_mesh`` raises a generic
    shape error, but the fix on a CPU host is a specific incantation that
    must be set BEFORE jax initializes — name it.
    """
    need = n_data * n_model
    have = len(jax.devices())
    if have < need:
        raise RuntimeError(
            f"make_debug_mesh({n_data}, {n_model}) needs {need} devices "
            f"but jax sees {have}.  On a CPU host, set "
            f'XLA_FLAGS="--xla_force_host_platform_device_count={need}" '
            "in the environment (or via os.environ) BEFORE importing/"
            "initializing jax — it has no effect once jax has picked its "
            "backend.  Tests should use the run_sharded fixture from "
            "tests/conftest.py, which spawns a fresh subprocess with the "
            "flag set.")
    return make_mesh_compat((n_data, n_model), ("data", "model"))


def make_lane_mesh(shape, axes=None):
    """Data-axes-only mesh for ``solve(mesh=...)`` lane sharding.

    Axis names default to ``("data",)`` for 1-d shapes and
    ``("pod", "data")`` for 2-d — the axes ``repro.parallel`` shards lanes
    over.  Same eager device-count check as ``make_debug_mesh``.
    """
    shape = tuple(shape)
    if axes is None:
        axes = {1: ("data",), 2: ("pod", "data")}.get(len(shape))
        if axes is None:
            raise ValueError(
                f"make_lane_mesh: pass axes= for a {len(shape)}-d shape "
                "(defaults exist for 1-d and 2-d only)")
    need = math.prod(shape)
    have = len(jax.devices())
    if have < need:
        raise RuntimeError(
            f"make_lane_mesh({shape}) needs {need} devices but jax sees "
            f'{have}.  On a CPU host, set XLA_FLAGS='
            f'"--xla_force_host_platform_device_count={need}" BEFORE jax '
            "initializes (tests: use the run_sharded fixture in "
            "tests/conftest.py).")
    return make_mesh_compat(shape, axes)
