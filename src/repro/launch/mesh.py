"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
initialization.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    jax.sharding.AxisType) only exist in newer releases; Auto is the
    default there, so omitting the kwarg on older jax is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
    TP ("model") stays inside a pod; DP spans ("pod", "data").
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small host-device mesh for CI tests (requires
    xla_force_host_platform_device_count >= n_data*n_model)."""
    return make_mesh_compat((n_data, n_model), ("data", "model"))
