"""Deterministic synthetic LM token pipeline.

Generates a reproducible Zipf-ish token stream with short-range structure
(so the loss actually decreases during the example runs).  ``TokenPipeline``
is an infinite iterator of sharded host batches: each host materializes
only its slice of the global batch (what a real distributed loader does),
keyed by (step, host_id) so restarts are exactly resumable — the
fault-tolerance path in launch/train.py relies on that.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_lm_batch(step: int, batch: int, seq_len: int, vocab: int,
                       seed: int = 0) -> dict:
    """Markov-ish synthetic tokens: t_{i+1} = (a*t_i + noise) % vocab."""
    rng = np.random.default_rng(np.uint64(seed * 1_000_003 + step))
    first = rng.integers(0, vocab, size=(batch, 1))
    mult = 6364136223846793005 % vocab or 1
    noise = rng.integers(0, 17, size=(batch, seq_len - 1))
    toks = [first]
    for i in range(seq_len - 1):
        nxt = (toks[-1] * mult + 7 + noise[:, i:i + 1]) % vocab
        toks.append(nxt)
    tokens = np.concatenate(toks, axis=1).astype(np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


@dataclasses.dataclass
class TokenPipeline:
    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    start_step: int = 0

    def __iter__(self) -> Iterator[dict]:
        step = self.start_step
        per_host = self.global_batch // self.n_hosts
        while True:
            b = synthetic_lm_batch(step * self.n_hosts + self.host_id,
                                   per_host, self.seq_len + 1, self.vocab,
                                   self.seed)
            yield {k: jnp.asarray(v) for k, v in b.items()}
            step += 1
