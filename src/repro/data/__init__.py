from .tokens import synthetic_lm_batch, TokenPipeline
from .tabular import make_tabular_dataset
from .physics_gen import generate_trajectories

__all__ = ["synthetic_lm_batch", "TokenPipeline", "make_tabular_dataset",
           "generate_trajectories"]
