"""Synthetic tabular datasets standing in for the paper's UCI benchmarks.

The paper trains CNFs on MiniBooNE/GAS/POWER/HEPMASS/BSDS300.  Offline we
generate Gaussian-mixture data with matching dimensionalities so the Table 2
benchmark exercises identical model/solver shapes and produces meaningful
NLL curves.
"""
from __future__ import annotations

import numpy as np

PAPER_DIMS = {"miniboone": 43, "gas": 8, "power": 6, "hepmass": 21,
              "bsds300": 63}
# number of stacked CNF components the paper used per dataset
PAPER_M = {"miniboone": 1, "gas": 5, "power": 5, "hepmass": 10,
           "bsds300": 2}


def make_tabular_dataset(name: str, n: int = 4096, seed: int = 0):
    dim = PAPER_DIMS[name]
    rng = np.random.default_rng(seed)
    k = 5
    means = rng.normal(0, 2.0, size=(k, dim))
    scales = rng.uniform(0.3, 0.8, size=(k, dim))
    comps = rng.integers(0, k, size=n)
    x = means[comps] + rng.normal(size=(n, dim)) * scales[comps]
    x = (x - x.mean(0)) / (x.std(0) + 1e-6)
    return x.astype(np.float32)
