"""Ground-truth PDE trajectory generation (KdV, Cahn-Hilliard).

Fine-step RK4 on periodic finite-difference discretizations; snapshots at
interval ``dt`` form (u_k, u_{k+1}) training pairs, matching the HNN++
experimental protocol the paper follows (Sec. 5.2).
"""
from __future__ import annotations

import numpy as np


def _dx(u, dx):
    return (np.roll(u, -1, -1) - np.roll(u, 1, -1)) / (2 * dx)


def _lap(u, dx):
    return (np.roll(u, -1, -1) - 2 * u + np.roll(u, 1, -1)) / (dx * dx)


def _kdv_rhs(u, dx, delta2=0.022 ** 2 * 100):
    return -u * _dx(u, dx) - delta2 * _dx(_lap(u, dx), dx)


def _ch_rhs(u, dx, gamma=0.01):
    return _lap(u ** 3 - u - gamma * _lap(u, dx), dx)


def generate_trajectories(system: str, n_traj: int = 8, grid: int = 64,
                          dx: float = 0.5, dt: float = 0.1,
                          n_snapshots: int = 32, seed: int = 0,
                          substeps: int = 200):
    """Returns snapshots (n_traj, n_snapshots, grid) float32."""
    rng = np.random.default_rng(seed)
    rhs = {"kdv": _kdv_rhs, "cahn_hilliard": _ch_rhs}[system]
    L = grid * dx
    xg = np.arange(grid) * dx
    trajs = np.zeros((n_traj, n_snapshots, grid), np.float32)
    for t in range(n_traj):
        if system == "kdv":
            # sum of two random solitons
            u = np.zeros(grid)
            for _ in range(2):
                c = rng.uniform(0.5, 2.0)
                x0 = rng.uniform(0, L)
                arg = np.sqrt(c) / 2 * ((xg - x0 + L / 2) % L - L / 2)
                u += 3 * c / np.cosh(np.clip(arg, -20, 20)) ** 2 * 0.1
        else:
            u = 0.1 * rng.normal(size=grid)
        h = dt / substeps
        for s in range(n_snapshots):
            trajs[t, s] = u
            for _ in range(substeps):
                k1 = rhs(u, dx)
                k2 = rhs(u + 0.5 * h * k1, dx)
                k3 = rhs(u + 0.5 * h * k2, dx)
                k4 = rhs(u + h * k3, dx)
                u = u + h / 6 * (k1 + 2 * k2 + 2 * k3 + k4)
    return trajs
