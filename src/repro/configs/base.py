"""Architecture / run configuration dataclasses.

An ArchConfig is a complete, declarative description of one model: the layer
pattern (a repeating unit scanned over depth + optional prefix layers), the
mixer/FFN hyperparameters, and the training-mode knobs (node_mode = the
paper's neural-ODE depth formulation + gradient scheme).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.nn.attention import AttnConfig
from repro.nn.mamba import MambaConfig
from repro.nn.moe import MoEConfig
from repro.nn.xlstm import XLSTMConfig


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str   # "attn" | "mla" | "mamba" | "mlstm" | "slstm"
    ffn: str     # "dense" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class NodeConfig:
    """The paper's technique as a first-class training mode.

    mode:
      "off"    — standard discrete residual stack.
      "node"   — depth-time neural ODE over the layer stack:
                 f(x, t) = unit_{floor(t*R)}(x), integrated with ``method``
                 over [0,1] with n_steps (= R by default).  With
                 method="euler" the forward map is IDENTICAL to the discrete
                 stack, so grad_mode="symplectic" gives exact gradients with
                 O(R + s + one-unit) live memory.
    grad_mode: a gradient strategy for ``repro.core.solve`` — either a
      registered name (symplectic | backprop | remat_step | remat_solve |
      adjoint) or a ``GradientStrategy`` instance carrying its own knobs
      (e.g. ``ContinuousAdjoint(steps_multiplier=4)``); resolved via
      ``repro.core.as_gradient`` at the solve call (core/api.py).
    combine_backend: auto | jnp | pallas — how RK stage combinations over the
      stacked slope buffers execute (auto = Pallas kernel on TPU, jnp oracle
      elsewhere; see core/combine.py).
    """
    mode: str = "off"
    method: str = "euler"
    n_steps: int = 0               # 0 => one step per repeat unit
    grad_mode: object = "symplectic"
    combine_backend: str = "auto"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: Tuple[LayerSpec, ...]
    prefix: Tuple[LayerSpec, ...] = ()
    # attention
    qk_norm: bool = False
    window: Optional[int] = None
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0
    mla_kv_lora: int = 0           # >0 enables MLA fields
    mla_rope_dim: int = 64
    mla_nope_dim: int = 128
    mla_v_dim: int = 128
    # moe
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_shared: int = 0
    moe_shared_d_ff: int = 0
    # ssm
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    xlstm_heads: int = 4
    # enc-dec / frontends
    encdec: bool = False
    enc_layers: int = 0
    frontend: str = "none"         # none | audio | patch
    d_frontend: int = 0
    # misc
    norm_eps: float = 1e-6
    residual_scale: float = 1.0    # minicpm depth-scaled residuals
    tie_embeddings: bool = False
    # training mode
    node: NodeConfig = NodeConfig()
    remat: bool = True             # checkpoint each scanned unit
    scan_unit: bool = True         # lax.scan over repeat units
    use_pallas: Optional[bool] = None

    @property
    def n_repeats(self) -> int:
        body = self.n_layers - len(self.prefix)
        assert body % len(self.pattern) == 0, \
            (self.name, body, len(self.pattern))
        return body // len(self.pattern)

    def attn_config(self) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
            qk_norm=self.qk_norm, window=self.window,
            rope_theta=self.rope_theta, rotary_pct=self.rotary_pct,
            mla=self.mla_kv_lora > 0, kv_lora=self.mla_kv_lora or 512,
            rope_head_dim=self.mla_rope_dim, nope_head_dim=self.mla_nope_dim,
            v_head_dim=self.mla_v_dim)

    def moe_config(self) -> MoEConfig:
        return MoEConfig(
            d_model=self.d_model, d_ff=self.moe_d_ff or self.d_ff,
            n_experts=self.moe_experts, top_k=self.moe_top_k,
            n_shared=self.moe_shared, shared_d_ff=self.moe_shared_d_ff)

    def mamba_config(self) -> MambaConfig:
        return MambaConfig(d_model=self.d_model,
                           d_state=self.mamba_d_state,
                           d_conv=self.mamba_d_conv,
                           expand=self.mamba_expand)

    def xlstm_config(self) -> XLSTMConfig:
        return XLSTMConfig(d_model=self.d_model, n_heads=self.xlstm_heads)

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str          # train_4k | prefill_32k | decode_32k | long_500k
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
