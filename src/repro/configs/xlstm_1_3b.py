"""xlstm-1.3b [ssm]: 48L d_model=2048 4H vocab=50304 — mLSTM + sLSTM
blocks at the paper's 7:1 ratio (sLSTM every 8th block); no separate FFN
(both blocks carry internal up/down projections).
[arXiv:2405.04517; unverified]"""
from .base import ArchConfig, LayerSpec

_UNIT = tuple([LayerSpec("mlstm", "none")] * 7 + [LayerSpec("slstm", "none")])

FULL = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    d_model=2048, n_layers=48, n_heads=4, n_kv_heads=4, head_dim=512,
    d_ff=0, vocab=50304,
    pattern=_UNIT,
    xlstm_heads=4, tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="xlstm-1.3b-smoke", family="ssm",
    d_model=64, n_layers=8, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=0, vocab=256,
    pattern=_UNIT,
    xlstm_heads=4, tie_embeddings=True,
)
