"""seamless-m4t-medium [audio]: enc-dec, 12L each side, d_model=1024 16H
(MHA) d_ff=4096 vocab=256206 — the speech frontend is a STUB per the
assignment: input_specs() provides precomputed fbank-stacked frames
(B, S, 160) which a linear frontend projects to d_model.
[arXiv:2308.11596; hf]"""
from .base import ArchConfig, LayerSpec

FULL = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    d_model=1024, n_layers=12, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=256206,
    pattern=(LayerSpec("attn", "dense"),),
    encdec=True, enc_layers=12,
    frontend="audio", d_frontend=160,
)

SMOKE = ArchConfig(
    name="seamless-m4t-medium-smoke", family="audio",
    d_model=64, n_layers=2, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256,
    pattern=(LayerSpec("attn", "dense"),),
    encdec=True, enc_layers=2,
    frontend="audio", d_frontend=16,
)
