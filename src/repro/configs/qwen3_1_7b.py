"""qwen3-1.7b [dense]: 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936 — qk_norm, head_dim=128, tied embeddings.
[hf:Qwen/Qwen3-8B; hf]"""
from .base import ArchConfig, LayerSpec

FULL = ArchConfig(
    name="qwen3-1.7b", family="dense",
    d_model=2048, n_layers=28, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=6144, vocab=151936,
    pattern=(LayerSpec("attn", "dense"),),
    qk_norm=True, rope_theta=1e6, tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="qwen3-1.7b-smoke", family="dense",
    d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=128, vocab=256,
    pattern=(LayerSpec("attn", "dense"),),
    qk_norm=True, tie_embeddings=True,
)
