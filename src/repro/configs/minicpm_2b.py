"""minicpm-2b [dense]: 40L d_model=2304 36H (MHA kv=36) d_ff=5760
vocab=122753 — llama-like with depth-scaled residuals; trained with the
WSD schedule (optim/schedules.py). [arXiv:2404.06395; hf]"""
import math

from .base import ArchConfig, LayerSpec

FULL = ArchConfig(
    name="minicpm-2b", family="dense",
    d_model=2304, n_layers=40, n_heads=36, n_kv_heads=36, head_dim=64,
    d_ff=5760, vocab=122753,
    pattern=(LayerSpec("attn", "dense"),),
    residual_scale=1.4 / math.sqrt(40), tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="minicpm-2b-smoke", family="dense",
    d_model=64, n_layers=2, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256,
    pattern=(LayerSpec("attn", "dense"),),
    residual_scale=1.4 / math.sqrt(2), tie_embeddings=True,
)
