"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — Qwen2-0.5B language backbone; the InternViT frontend is a
STUB per the assignment: input_specs() provides precomputed patch
embeddings (B, 256, 1024) which a linear projector maps into the token
stream. [arXiv:2404.16821; hf]"""
from .base import ArchConfig, LayerSpec

N_PATCHES = 256  # one 448x448 tile

FULL = ArchConfig(
    name="internvl2-1b", family="vlm",
    d_model=896, n_layers=24, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab=151655,
    pattern=(LayerSpec("attn", "dense"),),
    rope_theta=1e6,
    frontend="patch", d_frontend=1024, tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="internvl2-1b-smoke", family="vlm",
    d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256,
    pattern=(LayerSpec("attn", "dense"),),
    frontend="patch", d_frontend=32, tie_embeddings=True,
)
