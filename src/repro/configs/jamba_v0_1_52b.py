"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2 — Mamba+attention 1:7 interleave (one
attention layer per 8-layer Jamba block, at index 4), MoE every 2 layers.
[arXiv:2403.19887; hf]"""
from .base import ArchConfig, LayerSpec


def _jamba_unit():
    unit = []
    for i in range(8):
        mixer = "attn" if i == 4 else "mamba"
        ffn = "moe" if i % 2 == 1 else "dense"
        unit.append(LayerSpec(mixer, ffn))
    return tuple(unit)


FULL = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    d_model=4096, n_layers=32, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=65536,
    pattern=_jamba_unit(),
    moe_experts=16, moe_top_k=2, moe_d_ff=14336,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
)

SMOKE = ArchConfig(
    name="jamba-v0.1-52b-smoke", family="hybrid",
    d_model=64, n_layers=8, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256,
    pattern=_jamba_unit(),
    moe_experts=4, moe_top_k=2, moe_d_ff=64,
    mamba_d_state=8, mamba_d_conv=4, mamba_expand=2,
)
