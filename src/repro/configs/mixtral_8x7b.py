"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from .base import ArchConfig, LayerSpec

FULL = ArchConfig(
    name="mixtral-8x7b", family="moe",
    d_model=4096, n_layers=32, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000,
    pattern=(LayerSpec("attn", "moe"),),
    window=4096, rope_theta=1e6,
    moe_experts=8, moe_top_k=2, moe_d_ff=14336,
)

SMOKE = ArchConfig(
    name="mixtral-8x7b-smoke", family="moe",
    d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256,
    pattern=(LayerSpec("attn", "moe"),),
    window=32, moe_experts=4, moe_top_k=2, moe_d_ff=128,
)
