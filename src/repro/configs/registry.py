"""Central arch registry: ``--arch <id>`` resolution for all launchers."""
from __future__ import annotations

import importlib

from .base import ArchConfig

_MODULES = {
    "mixtral-8x7b": "mixtral_8x7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen3-1.7b": "qwen3_1_7b",
    "minicpm-2b": "minicpm_2b",
    "qwen3-0.6b": "qwen3_0_6b",
    "stablelm-12b": "stablelm_12b",
    "internvl2-1b": "internvl2_1b",
    "xlstm-1.3b": "xlstm_1_3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}

ARCH_IDS = tuple(_MODULES)

# archs whose attention is strictly quadratic-full -> long_500k is skipped
# (see DESIGN.md §Arch-applicability).  mixtral (SWA), xlstm (ssm) and
# jamba (hybrid) run long_500k.
FULL_ATTENTION_ARCHS = frozenset({
    "deepseek-v2-lite-16b", "qwen3-1.7b", "minicpm-2b", "qwen3-0.6b",
    "stablelm-12b", "internvl2-1b", "seamless-m4t-medium",
})


def _mod(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_arch(arch_id: str) -> ArchConfig:
    return _mod(arch_id).FULL


def get_smoke_arch(arch_id: str) -> ArchConfig:
    return _mod(arch_id).SMOKE


def cell_is_applicable(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k" and arch_id in FULL_ATTENTION_ARCHS:
        return False
    return True
