"""qwen3-0.6b [dense]: 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 — qk_norm, head_dim=128 (projected above d_model), tied.
[hf:Qwen/Qwen3-8B; hf]"""
from .base import ArchConfig, LayerSpec

FULL = ArchConfig(
    name="qwen3-0.6b", family="dense",
    d_model=1024, n_layers=28, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=3072, vocab=151936,
    pattern=(LayerSpec("attn", "dense"),),
    qk_norm=True, rope_theta=1e6, tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="qwen3-0.6b-smoke", family="dense",
    d_model=32, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab=128,
    pattern=(LayerSpec("attn", "dense"),),
    qk_norm=True, tie_embeddings=True,
)
