"""Architecture registry: one module per assigned arch + paper workloads."""
from .base import SHAPES, ArchConfig, LayerSpec, NodeConfig, ShapeConfig
from .registry import ARCH_IDS, get_arch, get_smoke_arch

__all__ = ["ArchConfig", "LayerSpec", "NodeConfig", "ShapeConfig", "SHAPES",
           "ARCH_IDS", "get_arch", "get_smoke_arch"]
