"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352 — partial rotary (25%), head_dim=160.
[hf:stabilityai/stablelm-2-1_6b; hf]"""
from .base import ArchConfig, LayerSpec

FULL = ArchConfig(
    name="stablelm-12b", family="dense",
    d_model=5120, n_layers=40, n_heads=32, n_kv_heads=8, head_dim=160,
    d_ff=13824, vocab=100352,
    pattern=(LayerSpec("attn", "dense"),),
    rotary_pct=0.25,
)

SMOKE = ArchConfig(
    name="stablelm-12b-smoke", family="dense",
    d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256,
    pattern=(LayerSpec("attn", "dense"),),
    rotary_pct=0.25,
)
