"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H (MLA kv_lora=512)
vocab=102400, MoE 64 routed experts top-6 + 2 shared, per-expert d_ff=1408.
First layer uses a dense FFN (d_ff=10944), per the HF config.
[arXiv:2405.04434; hf]

NOTE on the assignment line: it reads "MoE 64e top-6 — 2 shared+160 routed".
64 routed experts is the v2-LITE config (160 routed is full V2); we follow
the "MoE 64e" tag + 2 shared.
"""
from .base import ArchConfig, LayerSpec

FULL = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    d_model=2048, n_layers=27, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944, vocab=102400,
    prefix=(LayerSpec("mla", "dense"),),
    pattern=(LayerSpec("mla", "moe"),),
    mla_kv_lora=512, mla_rope_dim=64, mla_nope_dim=128, mla_v_dim=128,
    moe_experts=64, moe_top_k=6, moe_d_ff=1408,
    moe_shared=2, moe_shared_d_ff=2816,
)

SMOKE = ArchConfig(
    name="deepseek-v2-lite-16b-smoke", family="moe",
    d_model=64, n_layers=3, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256,
    prefix=(LayerSpec("mla", "dense"),),
    pattern=(LayerSpec("mla", "moe"),),
    mla_kv_lora=32, mla_rope_dim=8, mla_nope_dim=16, mla_v_dim=16,
    moe_experts=8, moe_top_k=2, moe_d_ff=32, moe_shared=2,
    moe_shared_d_ff=64,
)
