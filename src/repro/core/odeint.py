"""Unified odeint front-end: one entry point, five gradient modes.

    y = odeint(f, x0, params, t0=0., t1=1., method="dopri5",
               grad_mode="symplectic", n_steps=16)            # fixed grid
    y = odeint(f, x0, params, ..., adaptive=AdaptiveConfig(...))
    ys = odeint(f, x0, params, ts=jnp.array([.25, .5, 1.]), ...)  # SaveAt

``grad_mode``:
  symplectic   — the paper: exact gradient, memory O(N + s + L)   [default]
  backprop     — naive: exact gradient, memory O(N s L)
  remat_step   — ANODE/ACA: exact gradient, memory O(N + s L)
  remat_solve  — baseline scheme: exact gradient, memory O(N s L) in bwd
  adjoint      — continuous adjoint: approximate gradient, memory O(L)

``ts`` (SaveAt): observation times.  When given, the return value is the
solution at each t in ``ts``, stacked along a new leading axis (len(ts) per
leaf), and the solve ends at ts[-1] — pass t1 by including it in ts; passing
both is an error.  ``ts`` must be monotone in the direction of integration.
Supported by ALL five gradient modes on fixed grids; with ``adaptive`` by
symplectic/adjoint (reverse-differentiable) and backprop (forward value and
JVP only — reverse-mode through the adaptive lax.while_loop is unsupported,
as for the plain adaptive backprop solve; use grad_mode="symplectic" for
gradients of the realized adaptive map).  ``ts_mode``:

  segment — split the solve into checkpointed segments at the observation
            times; every observation is a segment endpoint, so the
            differentiated map is exact (the symplectic mode's backward
            pass runs Algorithm 2 per segment with the observation
            cotangents injected at the boundaries, keeping the exact-
            gradient guarantee).  Fixed-grid solves take ``n_steps`` PER
            SEGMENT; adaptive solves thread the controller step across
            segments and apply ``max_steps`` per segment.  Segments run
            inside one lax.scan, so trace size and compile time are O(1)
            in len(ts) (docs/adaptive.md).                    [auto default]
  dense   — one unsegmented adaptive solve + 4th-order Hermite dense-output
            interpolation at ts (StageCombiner.interpolate), so observation
            times never perturb the step controller.  Observation error is
            O(h^4); only grad_mode="backprop" with ``adaptive`` (and
            odeint_with_stats) support it, and like every adaptive
            backprop path it is forward-value/JVP only.

``combine_backend`` selects how every RK stage linear combination (forward
stage states, step update, embedded error, the symplectic backward
Lambda/lambda recursions, and the dense-output interpolation rows) is
executed over the stacked stage buffers:

  auto    — Pallas ``butcher_combine`` kernel on TPU, jnp oracle elsewhere
  jnp     — one fused single-pass contraction per combine (dtype-preserving;
            exact-to-rounding in float64)
  pallas  — always the Pallas kernel (interpret mode off-TPU; f32 accumulate)

Adaptive solves that exhaust max_steps/max_attempts without reaching the
target time follow ``AdaptiveConfig.on_failure`` ("nan" poison by default;
see docs/adaptive.md).

See docs/stage_combine.md for the stacked-buffer layout and the HBM-pass
arithmetic motivating the fused path, and docs/adaptive.md for the step
controller and SaveAt design.

The vector field signature is f(x, t, params) -> dx/dt over arbitrary pytrees.
Times t0/t1/ts are not differentiated (zero cotangents), matching the paper's
setting where T is fixed.
"""
from __future__ import annotations

from typing import Any, Optional, Union

import jax
import jax.numpy as jnp

from .adjoint import odeint_adjoint, odeint_adjoint_adaptive
from .backprop import odeint_backprop, odeint_remat_solve, odeint_remat_step
from .combine import resolve_backend
from .rk import (AdaptiveConfig, VectorField, apply_on_failure,
                 hermite_observe, rk_solve_adaptive,
                 rk_solve_adaptive_saveat_stacked, rk_solve_fixed,
                 segment_starts)
from .symplectic import (odeint_symplectic, odeint_symplectic_adaptive,
                         odeint_symplectic_saveat,
                         odeint_symplectic_saveat_adaptive)
from .tableau import ButcherTableau, get_tableau

GRAD_MODES = ("symplectic", "backprop", "remat_step", "remat_solve",
              "adjoint")
TS_MODES = ("auto", "segment", "dense")


def _as_ts(ts, dtype) -> jnp.ndarray:
    ts = jnp.asarray(ts, dtype=dtype)
    if ts.ndim != 1 or ts.shape[0] == 0:
        raise ValueError("ts must be a non-empty 1-D array of observation "
                         f"times; got shape {ts.shape}")
    return ts


def _segmented(solve_one, x0, t0, ts):
    """Generic SaveAt segmentation: chain per-segment solves, stack the
    segment endpoints.  Observation cotangents are injected at the segment
    boundaries automatically by reverse-mode through the composition (each
    observation feeds both the output and the next segment's input).

    ONE ``lax.scan`` over the segments: every segment shares the same step
    budget (n_steps fixed grid / max_steps adaptive), so the per-segment
    solve is a single traced scan body and trace/jaxpr size is O(1) in the
    number of observations (see docs/adaptive.md)."""
    def body(x, seg):
        a, b = seg
        x = solve_one(x, a, b)
        return x, x

    _, obs = jax.lax.scan(body, x0, (segment_starts(t0, ts), ts))
    return obs


def odeint(f: VectorField, x0, params, *, t0=0.0, t1=None,
           ts=None, ts_mode: str = "auto",
           method: Union[str, ButcherTableau] = "dopri5",
           grad_mode: str = "symplectic",
           n_steps: int = 16,
           adaptive: Optional[AdaptiveConfig] = None,
           adjoint_adaptive_cfg: Optional[AdaptiveConfig] = None,
           adjoint_steps_multiplier: int = 1,
           combine_backend: str = "auto"):
    tab = get_tableau(method) if isinstance(method, str) else method
    if grad_mode not in GRAD_MODES:
        raise ValueError(f"grad_mode {grad_mode!r} not in {GRAD_MODES}")
    if ts_mode not in TS_MODES:
        raise ValueError(f"ts_mode {ts_mode!r} not in {TS_MODES}")
    resolve_backend(combine_backend)  # eager validation, single source
    t0 = jnp.asarray(t0, dtype=jnp.result_type(float))

    if ts is not None:
        if t1 is not None:
            raise ValueError(
                "pass EITHER t1 or ts: with observation times the solve "
                "ends at ts[-1] (include the end time in ts)")
        ts = _as_ts(ts, t0.dtype)
        ts_mode = "segment" if ts_mode == "auto" else ts_mode

        if ts_mode == "dense":
            if adaptive is None or grad_mode != "backprop":
                raise ValueError(
                    "ts_mode='dense' needs an adaptive solve with "
                    "grad_mode='backprop' (forward value / JVP only, like "
                    "every adaptive backprop path; odeint_with_stats gives "
                    "the non-differentiable equivalent, and ts_mode="
                    "'segment' with grad_mode='symplectic' gives exact "
                    "reverse-mode gradients)")
            sol = rk_solve_adaptive(f, tab, x0, t0, ts[-1], params,
                                    adaptive, combine_backend)
            obs = hermite_observe(f, tab, sol, params, ts, combine_backend)
            return apply_on_failure(obs, sol.succeeded, adaptive.on_failure)

        if adaptive is not None:
            if grad_mode == "symplectic":
                return odeint_symplectic_saveat_adaptive(
                    f, tab, adaptive, combine_backend, x0, t0, ts, params)
            if grad_mode == "backprop":
                obs, _ = rk_solve_adaptive_saveat_stacked(
                    f, tab, x0, t0, ts, params, adaptive, combine_backend)
                return obs
            if grad_mode == "adjoint":
                bwd = adjoint_adaptive_cfg or adaptive
                return _segmented(
                    lambda x, a, b: odeint_adjoint_adaptive(
                        f, tab, adaptive, bwd, combine_backend,
                        x, a, b, params),
                    x0, t0, ts)
            raise ValueError(
                f"grad_mode {grad_mode!r} unsupported with adaptive "
                "stepping")

        if grad_mode == "symplectic":
            return odeint_symplectic_saveat(f, tab, n_steps, combine_backend,
                                            x0, t0, ts, params)
        seg = {
            "backprop": lambda x, a, b: odeint_backprop(
                f, tab, n_steps, x, a, b, params, combine_backend),
            "remat_step": lambda x, a, b: odeint_remat_step(
                f, tab, n_steps, x, a, b, params, combine_backend),
            "remat_solve": lambda x, a, b: odeint_remat_solve(
                f, tab, n_steps, x, a, b, params, combine_backend),
            "adjoint": lambda x, a, b: odeint_adjoint(
                f, tab, n_steps, adjoint_steps_multiplier, combine_backend,
                x, a, b, params),
        }[grad_mode]
        return _segmented(seg, x0, t0, ts)

    t1 = jnp.asarray(1.0 if t1 is None else t1, dtype=t0.dtype)

    if adaptive is not None:
        if grad_mode == "symplectic":
            return odeint_symplectic_adaptive(f, tab, adaptive,
                                              combine_backend,
                                              x0, t0, t1, params)
        if grad_mode == "adjoint":
            bwd = adjoint_adaptive_cfg or adaptive
            return odeint_adjoint_adaptive(f, tab, adaptive, bwd,
                                           combine_backend,
                                           x0, t0, t1, params)
        if grad_mode == "backprop":
            # differentiable-through adaptive solve (expensive; for tests)
            sol = rk_solve_adaptive(f, tab, x0, t0, t1, params,
                                    adaptive, combine_backend)
            return apply_on_failure(sol.x_final, sol.succeeded,
                                    adaptive.on_failure)
        raise ValueError(
            f"grad_mode {grad_mode!r} unsupported with adaptive stepping")

    if grad_mode == "symplectic":
        return odeint_symplectic(f, tab, n_steps, combine_backend,
                                 x0, t0, t1, params)
    if grad_mode == "backprop":
        return odeint_backprop(f, tab, n_steps, x0, t0, t1, params,
                               combine_backend)
    if grad_mode == "remat_step":
        return odeint_remat_step(f, tab, n_steps, x0, t0, t1, params,
                                 combine_backend)
    if grad_mode == "remat_solve":
        return odeint_remat_solve(f, tab, n_steps, x0, t0, t1, params,
                                  combine_backend)
    if grad_mode == "adjoint":
        return odeint_adjoint(f, tab, n_steps, adjoint_steps_multiplier,
                              combine_backend, x0, t0, t1, params)
    raise AssertionError


def odeint_with_stats(f: VectorField, x0, params, *, t0=0.0, t1=None,
                      ts=None,
                      method: Union[str, ButcherTableau] = "dopri5",
                      n_steps: int = 16,
                      adaptive: Optional[AdaptiveConfig] = None,
                      combine_backend: str = "auto"):
    """Non-differentiable solve returning integration statistics.

    With ``ts``: fixed-grid solves segment at the observation times
    (n_steps per segment); adaptive solves run ONE unsegmented solve and
    observe via Hermite dense output, so the stats reflect the controller's
    own step sequence (2 extra f-evals per observation for the endpoint
    slopes).  Adaptive stats gain ``succeeded`` (bool: reached the target
    time within the budgets) and ``n_attempts``.
    """
    tab = get_tableau(method) if isinstance(method, str) else method
    resolve_backend(combine_backend)  # eager validation, single source
    t0 = jnp.asarray(t0, dtype=jnp.result_type(float))

    if ts is not None:
        if t1 is not None:
            raise ValueError("pass EITHER t1 or ts (the solve ends at "
                             "ts[-1])")
        ts = _as_ts(ts, t0.dtype)
        n_obs = ts.shape[0]
        if adaptive is None:
            obs = _segmented(
                lambda x, a, b: rk_solve_fixed(
                    f, tab, x, a, b, n_steps, params,
                    combine_backend).x_final,
                x0, t0, ts)
            return obs, {"n_steps": n_obs * n_steps,
                         "n_fevals": n_obs * n_steps * tab.s}
        sol = rk_solve_adaptive(f, tab, x0, t0, ts[-1], params, adaptive,
                                combine_backend)
        obs = hermite_observe(f, tab, sol, params, ts, combine_backend)
        return obs, {"n_steps": sol.n_accepted,
                     "n_fevals": sol.n_fevals + 2 * n_obs,
                     "n_attempts": sol.n_attempts,
                     "succeeded": sol.succeeded}

    t1 = jnp.asarray(1.0 if t1 is None else t1, dtype=t0.dtype)
    if adaptive is None:
        sol = rk_solve_fixed(f, tab, x0, t0, t1, n_steps, params,
                             combine_backend)
        # the fixed-grid driver skips the embedded error estimate, so the
        # cost is exactly s evaluations per step — including for tableaus
        # whose error weights would need an extra f(x_{n+1}) evaluation
        # (err_uses_fsal), which the old always-estimate path silently paid
        # without it ever being counted here.
        return sol.x_final, {"n_steps": n_steps,
                             "n_fevals": n_steps * tab.s}
    sol = rk_solve_adaptive(f, tab, x0, t0, t1, params, adaptive,
                            combine_backend)
    return sol.x_final, {"n_steps": sol.n_accepted,
                         "n_fevals": sol.n_fevals,
                         "n_attempts": sol.n_attempts,
                         "succeeded": sol.succeeded}
