"""Unified odeint front-end: one entry point, five gradient modes.

    y = odeint(f, x0, params, t0=0., t1=1., method="dopri5",
               grad_mode="symplectic", n_steps=16)            # fixed grid
    y = odeint(f, x0, params, ..., adaptive=AdaptiveConfig(...))

``grad_mode``:
  symplectic   — the paper: exact gradient, memory O(N + s + L)   [default]
  backprop     — naive: exact gradient, memory O(N s L)
  remat_step   — ANODE/ACA: exact gradient, memory O(N + s L)
  remat_solve  — baseline scheme: exact gradient, memory O(N s L) in bwd
  adjoint      — continuous adjoint: approximate gradient, memory O(L)

``combine_backend`` selects how every RK stage linear combination (forward
stage states, step update, embedded error, and the symplectic backward
Lambda/lambda recursions) is executed over the stacked slope buffers:

  auto    — Pallas ``butcher_combine`` kernel on TPU, jnp oracle elsewhere
  jnp     — one fused single-pass contraction per combine (dtype-preserving;
            exact-to-rounding in float64)
  pallas  — always the Pallas kernel (interpret mode off-TPU; f32 accumulate)

See docs/stage_combine.md for the stacked-buffer layout and the HBM-pass
arithmetic motivating the fused path.

The vector field signature is f(x, t, params) -> dx/dt over arbitrary pytrees.
Times t0/t1 are not differentiated (zero cotangents), matching the paper's
setting where T is fixed.
"""
from __future__ import annotations

from typing import Any, Optional, Union

import jax.numpy as jnp

from .adjoint import odeint_adjoint, odeint_adjoint_adaptive
from .backprop import odeint_backprop, odeint_remat_solve, odeint_remat_step
from .combine import resolve_backend
from .rk import (AdaptiveConfig, VectorField, rk_solve_adaptive,
                 rk_solve_fixed)
from .symplectic import odeint_symplectic, odeint_symplectic_adaptive
from .tableau import ButcherTableau, get_tableau

GRAD_MODES = ("symplectic", "backprop", "remat_step", "remat_solve",
              "adjoint")


def odeint(f: VectorField, x0, params, *, t0=0.0, t1=1.0,
           method: Union[str, ButcherTableau] = "dopri5",
           grad_mode: str = "symplectic",
           n_steps: int = 16,
           adaptive: Optional[AdaptiveConfig] = None,
           adjoint_adaptive_cfg: Optional[AdaptiveConfig] = None,
           adjoint_steps_multiplier: int = 1,
           combine_backend: str = "auto"):
    tab = get_tableau(method) if isinstance(method, str) else method
    if grad_mode not in GRAD_MODES:
        raise ValueError(f"grad_mode {grad_mode!r} not in {GRAD_MODES}")
    resolve_backend(combine_backend)  # eager validation, single source
    t0 = jnp.asarray(t0, dtype=jnp.result_type(float))
    t1 = jnp.asarray(t1, dtype=t0.dtype)

    if adaptive is not None:
        if grad_mode == "symplectic":
            return odeint_symplectic_adaptive(f, tab, adaptive,
                                              combine_backend,
                                              x0, t0, t1, params)
        if grad_mode == "adjoint":
            bwd = adjoint_adaptive_cfg or adaptive
            return odeint_adjoint_adaptive(f, tab, adaptive, bwd,
                                           combine_backend,
                                           x0, t0, t1, params)
        if grad_mode == "backprop":
            # differentiable-through adaptive solve (expensive; for tests)
            return rk_solve_adaptive(f, tab, x0, t0, t1, params,
                                     adaptive, combine_backend).x_final
        raise ValueError(
            f"grad_mode {grad_mode!r} unsupported with adaptive stepping")

    if grad_mode == "symplectic":
        return odeint_symplectic(f, tab, n_steps, combine_backend,
                                 x0, t0, t1, params)
    if grad_mode == "backprop":
        return odeint_backprop(f, tab, n_steps, x0, t0, t1, params,
                               combine_backend)
    if grad_mode == "remat_step":
        return odeint_remat_step(f, tab, n_steps, x0, t0, t1, params,
                                 combine_backend)
    if grad_mode == "remat_solve":
        return odeint_remat_solve(f, tab, n_steps, x0, t0, t1, params,
                                  combine_backend)
    if grad_mode == "adjoint":
        return odeint_adjoint(f, tab, n_steps, adjoint_steps_multiplier,
                              combine_backend, x0, t0, t1, params)
    raise AssertionError


def odeint_with_stats(f: VectorField, x0, params, *, t0=0.0, t1=1.0,
                      method: Union[str, ButcherTableau] = "dopri5",
                      n_steps: int = 16,
                      adaptive: Optional[AdaptiveConfig] = None,
                      combine_backend: str = "auto"):
    """Non-differentiable solve returning integration statistics."""
    tab = get_tableau(method) if isinstance(method, str) else method
    resolve_backend(combine_backend)  # eager validation, single source
    if adaptive is None:
        sol = rk_solve_fixed(f, tab, x0, t0, t1, n_steps, params,
                             combine_backend)
        # the fixed-grid driver skips the embedded error estimate, so the
        # cost is exactly s evaluations per step — including for tableaus
        # whose error weights would need an extra f(x_{n+1}) evaluation
        # (err_uses_fsal), which the old always-estimate path silently paid
        # without it ever being counted here.
        return sol.x_final, {"n_steps": n_steps,
                             "n_fevals": n_steps * tab.s}
    sol = rk_solve_adaptive(f, tab, x0, t0, t1, params, adaptive,
                            combine_backend)
    return sol.x_final, {"n_steps": sol.n_accepted,
                         "n_fevals": sol.n_fevals}
