"""Legacy ``odeint`` front-end — a thin compat shim over ``solve``.

DEPRECATED: use the composable API in core/api.py instead

    from repro.core import SaveAt, SymplecticAdjoint, solve
    sol = solve(f, x0, params, saveat=SaveAt(t1=1.0),
                gradient=SymplecticAdjoint(), stepping=16)

Both entry points here only translate the old stringly-typed kwargs onto
``solve`` and emit a ``DeprecationWarning`` (turned into an error for
internal callers by the pytest config).  The kwarg -> object mapping, and
the full capability matrix the old mode flags encoded, live in docs/api.md:

    grad_mode="symplectic"            -> gradient=SymplecticAdjoint()
    grad_mode="backprop"              -> gradient=DirectBackprop()
    grad_mode="remat_step"            -> gradient=RematStep()
    grad_mode="remat_solve"           -> gradient=RematSolve()
    grad_mode="adjoint",
      adjoint_steps_multiplier=k,
      adjoint_adaptive_cfg=cfg        -> gradient=ContinuousAdjoint(
                                             steps_multiplier=k,
                                             bwd_adaptive=cfg)
    t1=..., ts=...                    -> saveat=SaveAt(t1=...) / SaveAt(ts=...)
    ts_mode="dense"                   -> saveat=SaveAt(ts=..., dense=True)
    n_steps=N / adaptive=cfg          -> stepping=N / stepping=cfg
    combine_backend=...               -> backend=...

``odeint`` returns ``Solution.ys``; ``odeint_with_stats`` returns
``(Solution.ys, stats_dict)`` with the historical key set (fixed grids:
n_steps/n_fevals; adaptive: + n_attempts/succeeded) and the historical
no-poisoning behavior (failures are reported via ``stats["succeeded"]``,
never NaN-poisoned or raised).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Union

from .api import ContinuousAdjoint, DirectBackprop, SaveAt, as_gradient, solve
from .rk import AdaptiveConfig, VectorField
from .tableau import ButcherTableau

GRAD_MODES = ("symplectic", "backprop", "remat_step", "remat_solve",
              "adjoint")
TS_MODES = ("auto", "segment", "dense")


def _warn(name: str) -> None:
    warnings.warn(
        f"odeint-style entry point {name}() is deprecated: use "
        "repro.core.solve(f, x0, params, saveat=SaveAt(...), "
        "gradient=<strategy>, stepping=<n_steps|AdaptiveConfig>) instead "
        "(migration table in docs/api.md)",
        DeprecationWarning, stacklevel=3)


def _gradient_of(grad_mode: str, adjoint_steps_multiplier: int,
                 adjoint_adaptive_cfg: Optional[AdaptiveConfig]):
    if grad_mode == "adjoint":
        return ContinuousAdjoint(steps_multiplier=adjoint_steps_multiplier,
                                 bwd_adaptive=adjoint_adaptive_cfg)
    # historical behavior: the adjoint-only kwargs are silently ignored by
    # every other mode.
    return as_gradient(grad_mode)


def odeint(f: VectorField, x0, params, *, t0=0.0, t1=None,
           ts=None, ts_mode: str = "auto",
           method: Union[str, ButcherTableau] = "dopri5",
           grad_mode: str = "symplectic",
           n_steps: int = 16,
           adaptive: Optional[AdaptiveConfig] = None,
           adjoint_adaptive_cfg: Optional[AdaptiveConfig] = None,
           adjoint_steps_multiplier: int = 1,
           combine_backend: str = "auto",
           batch_axis: Optional[int] = None):
    """DEPRECATED compat shim: translate old kwargs onto ``solve``."""
    _warn("odeint")
    if ts_mode not in TS_MODES:
        raise ValueError(f"ts_mode {ts_mode!r} not in {TS_MODES}")
    if ts is not None:
        if t1 is not None:
            # SaveAt would catch this too; raise here to keep the exact
            # historical message.
            raise ValueError(
                "pass EITHER t1 or ts: with observation times the solve "
                "ends at ts[-1] (include the end time in ts)")
        saveat = SaveAt(ts=ts, dense=(ts_mode == "dense"))
    else:
        saveat = SaveAt(t1=1.0 if t1 is None else t1)
    sol = solve(f, x0, params, saveat=saveat, method=method,
                gradient=_gradient_of(grad_mode, adjoint_steps_multiplier,
                                      adjoint_adaptive_cfg),
                stepping=n_steps if adaptive is None else adaptive,
                backend=combine_backend, t0=t0, batch_axis=batch_axis)
    return sol.ys


def odeint_with_stats(f: VectorField, x0, params, *, t0=0.0, t1=None,
                      ts=None,
                      method: Union[str, ButcherTableau] = "dopri5",
                      n_steps: int = 16,
                      adaptive: Optional[AdaptiveConfig] = None,
                      combine_backend: str = "auto",
                      batch_axis: Optional[int] = None):
    """DEPRECATED compat shim: non-differentiable solve + stats dict.

    Translates onto ``solve`` with ``DirectBackprop`` and reshapes
    ``Solution.stats`` into the historical dict.  With ``ts`` and an
    adaptive config the observation scheme is Hermite dense output (ONE
    unsegmented solve), exactly as before; the historical behavior of
    reporting failure via ``stats["succeeded"]`` instead of the config's
    on_failure policy is preserved by overriding the policy to "ignore".
    """
    _warn("odeint_with_stats")
    if ts is not None and t1 is not None:
        raise ValueError("pass EITHER t1 or ts (the solve ends at ts[-1])")
    if ts is not None:
        saveat = SaveAt(ts=ts, dense=(adaptive is not None))
    else:
        saveat = SaveAt(t1=1.0 if t1 is None else t1)
    if adaptive is None:
        stepping = n_steps
    else:
        stepping = dataclasses.replace(adaptive, on_failure="ignore")
    sol = solve(f, x0, params, saveat=saveat, method=method,
                gradient=DirectBackprop(), stepping=stepping,
                backend=combine_backend, t0=t0, batch_axis=batch_axis)
    if adaptive is None:
        stats = {"n_steps": sol.stats["n_steps"],
                 "n_fevals": sol.stats["n_fevals"]}
    else:
        stats = dict(sol.stats)
        stats["succeeded"] = sol.success
    return sol.ys, stats
