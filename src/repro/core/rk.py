"""Explicit Runge-Kutta integration over arbitrary pytree states.

Three drivers, all thin loops over the stepper state machine in
core/stepper.py (``init_state -> advance* -> finalize``):

  * ``rk_solve_fixed``    — N equal steps: a ``FixedStepper`` run as one
                            lax.scan over ``advance`` (scan, not while_loop,
                            so DirectBackprop / remat strategies can still
                            differentiate straight through it; used by the
                            LM node_mode and all dry-run cells).
  * ``rk_solve_adaptive`` — PI-controlled adaptive stepping: an
                            ``AdaptiveStepper`` run as one lax.while_loop
                            whose carry IS the ``SolverState`` — bounded
                            ``max_steps`` checkpoint buffers (used by the
                            CNF / physics experiments, mirroring the
                            paper's dopri5-adaptive setting).
  * ``rk_solve_adaptive_batched`` — B independent trajectories, one
                            while_loop, masked per-lane control: the SAME
                            stepper with a lane-batched ``SolverState``.

All record the step checkpoints {x_n, t_n, h_n} that Algorithm 1 of the
paper retains; computation graphs are never part of the residuals (the
gradient strategies in api.py decide what autodiff sees).  Because the
between-steps state is an explicit registered pytree, any solve can also be
paused, saved, restored, and resumed bit-identically — and the
continuous-batching serve engine (repro.serve) drives the same ``advance``
over a masked lane state, inserting new trajectories mid-flight.

Stage representation: slopes are held in a *stacked* buffer — one leading
stage dimension per leaf — and every stage linear combination (stage states,
the step update, the embedded error) goes through the StageCombiner
(core/combine.py), which fuses each combination into a single HBM pass and
dispatches between the jnp oracle and the Pallas ``butcher_combine`` kernel
via the ``combine_backend`` knob.  The fixed-grid driver never computes the
embedded error estimate (there is no step controller to consume it), saving
one error combine — and, for tableaus whose error weights reference
f(x_{n+1}), one whole network evaluation — per step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .combine import get_combiner
from .tableau import ButcherTableau
from .stepper import (  # noqa: F401  (re-exports: the step-level surface)
    ON_FAILURE_POLICIES, AdaptiveConfig, AdaptiveSolution, AdaptiveStepper,
    BatchedAdaptiveSolution, FixedSolution, FixedSolverState, FixedStepper,
    Pytree, SolverState, VectorField, _error_norm, _error_norm_lanes,
    _time_resolution, lane_bcast, lane_count, rk_stages, rk_step)


def time_zero_cotangent(t):
    """A zero cotangent whose aval MATCHES the primal time argument.

    The drivers integrate in ``jnp.result_type(float)`` internally, but a
    custom_vjp backward pass must return cotangents in the dtype the caller
    actually passed (e.g. a float32 ``t0`` under x64) — so each fwd stows
    the primal time values in the residuals and the bwd zeros them out
    here, instead of fabricating ``result_type(float)`` zeros.
    """
    return jnp.zeros_like(jnp.asarray(t))


def time_lift(t):
    """Lift a scalar time to a ``(1,)``-shaped array for a custom_vjp driver.

    The gradient drivers' custom_vjp boundaries must not expose RANK-0
    differentiable primal inputs: ``shard_map``'s transpose rule assigns
    backward out_names from the forward in_names, and on this jax a rank-0
    cotangent paired with a non-empty name set fails the spec check
    (``_SpecError``) — so ``jax.grad`` through
    ``shard_map(solve, ...)`` dies on scalar ``t0``/``t1``.  Every driver
    therefore takes its scalar times as ``(1,)`` arrays internally (the
    public wrappers lift here, the driver reads them back via
    ``time_unlift``), which keeps the custom_vjp's cotangent avals rank-1
    and sharding-legible.  Rank-1 times — ``SaveAt.ts``, and the (B,)
    per-lane horizons the batched drivers accept — are already lifted and
    pass through untouched.
    """
    t = jnp.asarray(t)
    return jnp.reshape(t, (1,)) if t.ndim == 0 else t


def time_unlift(tr):
    """Read a ``time_lift``-ed time back inside a driver: a ``(1,)``
    lifted scalar becomes the scalar again; per-lane ``(B,)`` arrays pass
    through.  (A genuine per-lane ``(1,)`` horizon for a B=1 batch also
    reads back scalar — the drivers broadcast shared times over lanes, so
    the solve is identical.)"""
    return tr[0] if tr.shape == (1,) else tr


def tree_scale_add(base: Pytree, terms) -> Pytree:
    """base + sum_i coef_i * tree_i via chained per-leaf AXPYs.

    ``terms`` is a list of (coef, tree).  Zero coefficients (python floats)
    are dropped at trace time.  This is the UNFUSED combination path — s+2
    HBM passes; the solver hot loop uses the StageCombiner instead.  Kept as
    the reference for tests and benchmarks/bench_combine.py.
    """
    terms = [(c, t) for (c, t) in terms
             if not (isinstance(c, float) and c == 0.0)]
    if not terms:
        return base
    leaves_b, treedef = jax.tree_util.tree_flatten(base)
    term_leaves = [jax.tree_util.tree_flatten(t)[0] for _, t in terms]
    coefs = [c for c, _ in terms]
    out = []
    for idx, lb in enumerate(leaves_b):
        acc = lb
        for c, leaves in zip(coefs, term_leaves):
            acc = acc + jnp.asarray(c, dtype=lb.dtype) * leaves[idx]
        out.append(acc)
    return jax.tree_util.tree_unflatten(treedef, out)


def rk_solve_fixed(f: VectorField, tab: ButcherTableau, x0, t0, t1,
                   n_steps: int, params,
                   combine_backend: str = "auto") -> FixedSolution:
    stepper = FixedStepper(f, tab, n_steps, combine_backend)
    state = stepper.run(stepper.init_state(x0, t0, t1), params)
    return stepper.finalize(state)


# ---------------------------------------------------------------------------
# Adaptive stepping (PI controller), bounded buffer of accepted checkpoints.
# ---------------------------------------------------------------------------

def rk_solve_adaptive(f: VectorField, tab: ButcherTableau, x0, t0, t1,
                      params, cfg: AdaptiveConfig,
                      combine_backend: str = "auto",
                      h0=None) -> AdaptiveSolution:
    """PI-controlled adaptive solve on [t0, t1].

    ``h0`` (optional, traced ok) seeds the controller with a step MAGNITUDE
    — e.g. the ``h_final`` of a preceding segment in a SaveAt solve — and
    falls back to ``cfg.initial_step`` when absent or zero.  The carried
    controller step ``h`` is never clamped: each trial uses
    ``h_eff = min(|h|, |t1 - t|)`` but the controller update is based on the
    unclamped ``h`` for landing steps — an accepted clamped step keeps
    ``h``, a rejected one retries from ``h * factor`` — so a tiny final
    step against the t1 boundary cannot collapse the step size for a
    continuation (or for a backward adjoint solve reusing the config),
    whether the landing trial succeeds or not.

    The whole driver is ``AdaptiveStepper.run``: one lax.while_loop over
    ``advance``, carrying the explicit ``SolverState`` — every controller
    rule (clamp, PI factor, commit, budgets) lives in ``advance`` and is
    shared verbatim with the batched driver and the serve engine.
    """
    stepper = AdaptiveStepper(f, tab, cfg, combine_backend)
    state = stepper.init_state(x0, t0, t1, h0)
    return stepper.finalize(stepper.run(state, params))


def _raise_on_failure_cb(ok):
    if not bool(ok):
        raise RuntimeError(
            "odeint: adaptive solver exhausted max_steps/max_attempts "
            "without reaching t1 (AdaptiveConfig(on_failure='raise'))")


def apply_on_failure(x_final: Pytree, succeeded, on_failure: str) -> Pytree:
    """Apply an AdaptiveConfig.on_failure policy to a solver result.

    ``succeeded`` may be a scalar (one trajectory) or a per-lane (B,)
    vector (``batch_axis=0`` — lane axis 0 of every leaf): "nan" poisons
    exactly the failed trajectories, "raise" raises when any failed.
    """
    if on_failure == "ignore":
        return x_final
    if on_failure == "raise":
        jax.debug.callback(_raise_on_failure_cb, jnp.all(succeeded))
        return x_final
    assert on_failure == "nan", on_failure

    def poison(l):
        if not jnp.issubdtype(l.dtype, jnp.inexact):
            return l
        return jnp.where(lane_bcast(succeeded, l), l,
                         jnp.full_like(l, jnp.nan))

    return jax.tree_util.tree_map(poison, x_final)


# Named alias for the per-lane reading at batched call sites; the policy
# logic lives once in apply_on_failure (lane_bcast handles both ranks).
apply_on_failure_lanes = apply_on_failure


# ---------------------------------------------------------------------------
# Batch-native adaptive stepping: one while_loop, masked per-lane control.
# ---------------------------------------------------------------------------

def rk_solve_adaptive_batched(f: VectorField, tab: ButcherTableau, x0,
                              t0, t1, params, cfg: AdaptiveConfig,
                              combine_backend: str = "auto",
                              h0=None) -> BatchedAdaptiveSolution:
    """Adaptive solve of B independent trajectories in ONE while_loop.

    ``x0`` is lane-batched (lane axis 0 of every leaf).  Each lane carries
    its own ``(t, h, n_accepted, n_attempts)`` controller state, its own
    error norm (``_error_norm_lanes``: the single-trajectory norm per lane,
    never pooled across the batch), and its own accept/reject decision —
    finished and rejected lanes are masked on commit, so no lane's
    stiffness can perturb another lane's accepted grid.  The loop runs
    until every lane lands (or exhausts its budgets), and each trial step
    evaluates ``f`` ONCE over the full batch (the stage combines stay fused
    through the StageCombiner under ``vmap``), so the hot path keeps its
    batched shape; iterations where some lanes are already done spend
    wasted lane-slots, which is the price of the fused evaluation
    (docs/batching.md quantifies the trade against lockstep batch-in-state
    solving).

    Every controller rule matches ``rk_solve_adaptive`` per lane — the
    unclamped-h carry for landing steps, the dtype-aware termination
    threshold, the PI factor — because it IS the same rule: both drivers
    run ``AdaptiveStepper.advance``, whose state is scalar () for a single
    trajectory and (B,) here — so lane b of the result is the
    single-trajectory solve of lane b to rounding (tests/test_batch.py).
    ``t0``/``t1``/``h0`` may be scalars (shared) or (B,) per-lane arrays.
    """
    B = lane_count(x0)
    stepper = AdaptiveStepper(f, tab, cfg, combine_backend)
    state = stepper.init_state(x0, t0, t1, h0, lanes=B)
    return stepper.finalize(stepper.run(state, params))


def rk_solve_adaptive_batched_saveat_stacked(
        f: VectorField, tab: ButcherTableau, x0, t0, ts: jnp.ndarray,
        params, cfg: AdaptiveConfig, combine_backend: str = "auto"):
    """Batched analogue of ``rk_solve_adaptive_saveat_stacked``: one scanned
    segment chain, per-lane controller state ``(x, h_final)`` threading
    across every observation boundary (each lane's landing step stays
    unclamped in ITS carry).  Observation times are shared across lanes.
    A lane whose segment fails is poisoned per ``cfg.on_failure`` without
    touching its batchmates, and the poison propagates to that lane's later
    observations.  Returns (obs, sols) with a leading len(ts) segment axis
    on every ``BatchedAdaptiveSolution`` field.
    """
    dtype = jnp.result_type(float)
    ts = jnp.asarray(ts, dtype)
    B = lane_count(x0)
    t_starts = segment_starts(t0, ts)

    def body(carry, seg):
        x, h = carry
        a, b = seg
        sol = rk_solve_adaptive_batched(f, tab, x, a, b, params, cfg,
                                        combine_backend, h0=h)
        x = apply_on_failure_lanes(sol.x_final, sol.succeeded,
                                   cfg.on_failure)
        sol = sol._replace(x_final=x)
        return (x, sol.h_final), sol

    _, sols = jax.lax.scan(body, (x0, jnp.zeros((B,), dtype)),
                           (t_starts, ts))
    return sols.x_final, sols


# ---------------------------------------------------------------------------
# SaveAt support: segmented adaptive solves + Hermite dense output.
# ---------------------------------------------------------------------------

def segment_starts(t0, ts: jnp.ndarray) -> jnp.ndarray:
    """Left endpoints of the observation segments: [t0, ts[0], ..., ts[-2]].

    Zipped with ``ts`` these are the (start, end) pairs every scanned
    SaveAt driver iterates over.
    """
    t0 = jnp.reshape(jnp.asarray(t0, ts.dtype), (1,))
    return jnp.concatenate([t0, ts[:-1]])


def rk_solve_adaptive_saveat_stacked(f: VectorField, tab: ButcherTableau,
                                     x0, t0, ts: jnp.ndarray, params,
                                     cfg: AdaptiveConfig,
                                     combine_backend: str = "auto"):
    """Adaptive solve observed at the times ``ts`` by segmenting the solve.

    One adaptive sub-solve per segment [t0, ts[0]], [ts[0], ts[1]], ...; the
    controller state threads across segments (each segment seeds its step
    from the previous segment's unclamped ``h_final``, so landing exactly on
    an observation time costs one clamped step, not a collapsed restart).
    A failed segment poisons its state per ``cfg.on_failure`` and the
    poison propagates to every later observation.

    The segments run inside ONE ``lax.scan`` (every segment shares the
    ``max_steps`` buffer bound, so shapes are uniform): trace size, jaxpr
    size, and compile time are O(1) in len(ts).

    Returns (obs, sols): ``obs`` the stacked observations (leading dim
    len(ts)), ``sols`` an AdaptiveSolution whose every field carries a
    leading len(ts) segment axis.
    """
    dtype = jnp.result_type(float)
    ts = jnp.asarray(ts, dtype)
    t_starts = segment_starts(t0, ts)

    def body(carry, seg):
        x, h = carry
        a, b = seg
        sol = rk_solve_adaptive(f, tab, x, a, b, params, cfg,
                                combine_backend, h0=h)
        x = apply_on_failure(sol.x_final, sol.succeeded, cfg.on_failure)
        sol = sol._replace(x_final=x)
        return (x, sol.h_final), sol

    # h0 = 0 makes the first segment fall back to cfg.initial_step.
    _, sols = jax.lax.scan(body, (x0, jnp.zeros((), dtype)),
                           (t_starts, ts))
    return sols.x_final, sols


def rk_solve_adaptive_saveat(f: VectorField, tab: ButcherTableau, x0, t0,
                             ts: jnp.ndarray, params, cfg: AdaptiveConfig,
                             combine_backend: str = "auto"):
    """List-of-segments convenience wrapper around the scanned driver.

    Returns (obs, sols) with ``sols`` a Python list of per-segment
    AdaptiveSolutions (unstacked views into the scanned buffers).  Solver
    hot paths use ``rk_solve_adaptive_saveat_stacked`` directly — the
    unstacking here costs O(len(ts)) trace equations and is meant for
    inspection and tests.
    """
    obs, stacked = rk_solve_adaptive_saveat_stacked(
        f, tab, x0, t0, ts, params, cfg, combine_backend)
    sols = [jax.tree_util.tree_map(lambda l: l[i], stacked)
            for i in range(ts.shape[0])]
    return obs, sols


def hermite_observe(f: VectorField, tab: ButcherTableau,
                    sol: AdaptiveSolution, params, taus: jnp.ndarray,
                    combine_backend: str = "auto") -> Pytree:
    """Dense-output observation of ONE adaptive solve at the times ``taus``.

    4th-order cubic-Hermite interpolation over the accepted step containing
    each tau (StageCombiner.interpolate — the same row-combine primitive as
    the Butcher rows).  The step endpoints come from the checkpoint buffer;
    their slopes are recomputed (2 extra f-evals per observation), so the
    step controller is never perturbed by observation times.  taus outside
    the integrated span clamp to the nearest endpoint.
    """
    combiner = get_combiner(tab, combine_backend)
    max_steps = sol.ts.shape[0]
    n_acc = sol.n_accepted
    last = jnp.maximum(n_acc - 1, 0)
    direction = jnp.sign(jnp.where(n_acc > 0, sol.hs[0], 1.0))
    valid = jnp.arange(max_steps) < n_acc
    keys = jnp.where(valid, direction * sol.ts, jnp.inf)

    def observe_one(tau):
        n = jnp.clip(jnp.searchsorted(keys, direction * tau,
                                      side="right") - 1, 0, last)
        t_n = sol.ts[n]
        h_n = sol.hs[n]
        x_n = jax.tree_util.tree_map(
            lambda b: jax.lax.dynamic_index_in_dim(b, n, 0, keepdims=False),
            sol.xs)
        # x_{n+1}: next checkpoint, or x_final for the last accepted step.
        is_last = n >= n_acc - 1
        x_n1 = jax.tree_util.tree_map(
            lambda b, xf: jnp.where(
                is_last, xf,
                jax.lax.dynamic_index_in_dim(
                    b, jnp.minimum(n + 1, max_steps - 1), 0,
                    keepdims=False)),
            sol.xs, sol.x_final)
        theta = jnp.clip((tau - t_n) / jnp.where(h_n == 0, 1.0, h_n),
                         0.0, 1.0)
        f0 = f(x_n, t_n, params)
        f1 = f(x_n1, t_n + h_n, params)
        out = combiner.interpolate(x_n, x_n1, f0, f1, h_n, theta)
        # degenerate solve (no accepted steps): the state never moved.
        return jax.tree_util.tree_map(
            lambda o, xf: jnp.where(n_acc > 0, o, xf), out, sol.x_final)

    # observe_one is elementwise in tau: ONE traced copy serves every
    # observation (and slope recomputations batch), instead of unrolling
    # the search + interpolate + 2-f-eval graph per tau.
    return jax.vmap(observe_one)(taus)
