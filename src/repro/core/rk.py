"""Explicit Runge-Kutta integration over arbitrary pytree states.

Two drivers:
  * ``rk_solve_fixed``    — N equal steps via lax.scan (deterministic shape;
                            used by the LM node_mode and all dry-run cells).
  * ``rk_solve_adaptive`` — PI-controlled adaptive stepping via lax.while_loop
                            with a bounded ``max_steps`` checkpoint buffer
                            (used by the CNF / physics experiments, mirroring
                            the paper's dopri5-adaptive setting).

Both record the step checkpoints {x_n, t_n, h_n} that Algorithm 1 of the paper
retains; computation graphs are never part of the residuals (the gradient
modes in odeint.py decide what autodiff sees).

Stage representation: slopes are held in a *stacked* buffer — one leading
stage dimension per leaf — and every stage linear combination (stage states,
the step update, the embedded error) goes through the StageCombiner
(core/combine.py), which fuses each combination into a single HBM pass and
dispatches between the jnp oracle and the Pallas ``butcher_combine`` kernel
via the ``combine_backend`` knob.  The fixed-grid driver never computes the
embedded error estimate (there is no step controller to consume it), saving
one error combine — and, for tableaus whose error weights reference
f(x_{n+1}), one whole network evaluation — per step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .combine import (StageCombiner, alloc_stages, append_stage,
                      get_combiner, set_stage)
from .tableau import ButcherTableau

Pytree = Any
VectorField = Callable[[Pytree, jnp.ndarray, Pytree], Pytree]
# f(x, t, params) -> dx/dt, pytree-in pytree-out.


def time_zero_cotangent(t):
    """A zero cotangent whose aval MATCHES the primal time argument.

    The drivers integrate in ``jnp.result_type(float)`` internally, but a
    custom_vjp backward pass must return cotangents in the dtype the caller
    actually passed (e.g. a float32 ``t0`` under x64) — so each fwd stows
    the primal time values in the residuals and the bwd zeros them out
    here, instead of fabricating ``result_type(float)`` zeros.
    """
    return jnp.zeros_like(jnp.asarray(t))


def tree_scale_add(base: Pytree, terms) -> Pytree:
    """base + sum_i coef_i * tree_i via chained per-leaf AXPYs.

    ``terms`` is a list of (coef, tree).  Zero coefficients (python floats)
    are dropped at trace time.  This is the UNFUSED combination path — s+2
    HBM passes; the solver hot loop uses the StageCombiner instead.  Kept as
    the reference for tests and benchmarks/bench_combine.py.
    """
    terms = [(c, t) for (c, t) in terms
             if not (isinstance(c, float) and c == 0.0)]
    if not terms:
        return base
    leaves_b, treedef = jax.tree_util.tree_flatten(base)
    term_leaves = [jax.tree_util.tree_flatten(t)[0] for _, t in terms]
    coefs = [c for c, _ in terms]
    out = []
    for idx, lb in enumerate(leaves_b):
        acc = lb
        for c, leaves in zip(coefs, term_leaves):
            acc = acc + jnp.asarray(c, dtype=lb.dtype) * leaves[idx]
        out.append(acc)
    return jax.tree_util.tree_unflatten(treedef, out)


def rk_stages(f: VectorField, tab: ButcherTableau, x, t, h, params,
              combiner: Optional[StageCombiner] = None):
    """Compute all stage states X_i and slopes k_i for one step.

    Returns (Xs, K): ``Xs`` is a list of s stage-state pytrees, ``K`` the
    stacked slope buffer (leading stage dim s per leaf).  Purely forward;
    the symplectic backward pass re-runs this from a checkpoint (Alg. 2
    lines 3-7).
    """
    combiner = combiner or get_combiner(tab)
    s = tab.s
    K = alloc_stages(s, x)
    Xs = []
    for i in range(s):
        Xi = combiner.stage_state(x, K, h, i)
        ki = f(Xi, t + tab.c[i] * h, params)
        K = set_stage(K, i, ki)
        Xs.append(Xi)
    return Xs, K


def rk_step(f: VectorField, tab: ButcherTableau, x, t, h, params,
            combiner: Optional[StageCombiner] = None,
            with_error: Optional[bool] = None):
    """One explicit RK step: returns (x_next, err_estimate_or_None).

    ``with_error=False`` skips the embedded error estimate (the fixed-grid
    drivers pass it; there is no controller to consume the estimate).  The
    default (None) computes it whenever the tableau has error weights.
    """
    combiner = combiner or get_combiner(tab)
    if with_error is None:
        with_error = tab.b_err is not None
    Xs, K = rk_stages(f, tab, x, t, h, params, combiner)
    if not (with_error and tab.b_err is not None):
        return combiner.solution(x, K, h), None
    if tab.err_uses_fsal:
        # the error weights reference k_{s+1} = f(x_{n+1}); the solution must
        # come first, then one extra evaluation extends the slope buffer.
        x_next = combiner.solution(x, K, h)
        K_err = append_stage(K, f(x_next, t + h, params))
        return x_next, combiner.error(x, K_err, h)
    # both rows (b, b_err) combine the same s slopes: fuse into ONE pass.
    return combiner.solution_and_error(x, K, h)


class FixedSolution(NamedTuple):
    x_final: Pytree
    xs: Pytree          # stacked checkpoints x_0..x_{N-1} (leading dim N)
    ts: jnp.ndarray     # t_0..t_{N-1}
    h: jnp.ndarray      # scalar step size


def rk_solve_fixed(f: VectorField, tab: ButcherTableau, x0, t0, t1,
                   n_steps: int, params,
                   combine_backend: str = "auto") -> FixedSolution:
    t0 = jnp.asarray(t0, dtype=jnp.result_type(float))
    t1 = jnp.asarray(t1, dtype=t0.dtype)
    h = (t1 - t0) / n_steps
    combiner = get_combiner(tab, combine_backend)

    def body(carry, n):
        x, = carry
        t = t0 + n.astype(t0.dtype) * h
        x_next, _ = rk_step(f, tab, x, t, h, params, combiner,
                            with_error=False)
        return (x_next,), (x, t)

    (xf,), (xs, ts) = jax.lax.scan(body, (x0,), jnp.arange(n_steps))
    return FixedSolution(xf, xs, ts, h)


# ---------------------------------------------------------------------------
# Adaptive stepping (PI controller), bounded buffer of accepted checkpoints.
# ---------------------------------------------------------------------------

ON_FAILURE_POLICIES = ("nan", "ignore", "raise")


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    rtol: float = 1e-6
    atol: float = 1e-8
    max_steps: int = 256          # checkpoint buffer bound (accepted steps)
    max_attempts: int = 4096      # total trial-step bound
    safety: float = 0.9
    min_factor: float = 0.2
    max_factor: float = 10.0
    initial_step: float = 0.01
    # what odeint does with x_final when the while-loop exits via the
    # max_steps / max_attempts budget without reaching t1:
    #   "nan"    — poison every inexact leaf with NaN  [default]
    #   "ignore" — return the truncated state as-is (pre-fix behaviour)
    #   "raise"  — jax.debug.callback that raises at dispatch time
    on_failure: str = "nan"

    def __post_init__(self):
        if self.on_failure not in ON_FAILURE_POLICIES:
            raise ValueError(f"on_failure {self.on_failure!r} not in "
                             f"{ON_FAILURE_POLICIES}")


class AdaptiveSolution(NamedTuple):
    x_final: Pytree
    xs: Pytree           # (max_steps, ...) accepted checkpoints, zero-padded
    ts: jnp.ndarray      # (max_steps,)
    hs: jnp.ndarray      # (max_steps,)
    n_accepted: jnp.ndarray  # int32 scalar
    n_fevals: jnp.ndarray    # int32 scalar
    succeeded: jnp.ndarray   # bool scalar: reached t1 within the budgets
    h_final: jnp.ndarray     # UNclamped controller step at exit (see below)
    n_attempts: jnp.ndarray  # int32 scalar: total trial steps (acc + rej)


def _error_norm(err, x, x_next, rtol, atol):
    leaves = zip(jax.tree_util.tree_leaves(err),
                 jax.tree_util.tree_leaves(x),
                 jax.tree_util.tree_leaves(x_next))
    total, count = 0.0, 0
    for e, a, b in leaves:
        scale = atol + rtol * jnp.maximum(jnp.abs(a), jnp.abs(b))
        # accumulate in >= f32 but NEVER below the state dtype: an f32 norm
        # under x64 quantizes the accept/reject decisions of an f64 solve
        # (caught by the repro.analysis dtype rule).
        r = (e / scale).astype(jnp.promote_types(e.dtype, jnp.float32))
        total = total + jnp.sum(r * r)
        count += r.size
    return jnp.sqrt(total / count)


def _time_resolution(t0, t1, dtype):
    """Smallest meaningful |t1 - t| for the termination test.

    The old fixed threshold (1e-14) is below float32 resolution for typical
    t, so with x64 disabled the loop could burn attempts re-trying steps
    whose ``t + h`` rounds back to ``t``.  Scale by the representable
    resolution of the interval instead: a few ulps of max(|t0|, |t1|,
    |t1 - t0|) in the working dtype.
    """
    eps = jnp.asarray(jnp.finfo(dtype).eps, dtype)
    scale = jnp.maximum(jnp.abs(t1 - t0),
                        jnp.maximum(jnp.abs(t0), jnp.abs(t1)))
    return 4.0 * eps * jnp.maximum(scale, eps)


def rk_solve_adaptive(f: VectorField, tab: ButcherTableau, x0, t0, t1,
                      params, cfg: AdaptiveConfig,
                      combine_backend: str = "auto",
                      h0=None) -> AdaptiveSolution:
    """PI-controlled adaptive solve on [t0, t1].

    ``h0`` (optional, traced ok) seeds the controller with a step MAGNITUDE
    — e.g. the ``h_final`` of a preceding segment in a SaveAt solve — and
    falls back to ``cfg.initial_step`` when absent or zero.  The carried
    controller step ``h`` is never clamped: each trial uses
    ``h_eff = min(|h|, |t1 - t|)`` but the controller update is based on the
    unclamped ``h`` for landing steps — an accepted clamped step keeps
    ``h``, a rejected one retries from ``h * factor`` — so a tiny final
    step against the t1 boundary cannot collapse the step size for a
    continuation (or for a backward adjoint solve reusing the config),
    whether the landing trial succeeds or not.
    """
    if tab.b_err is None:
        raise ValueError(f"tableau {tab.name} has no embedded error estimate")
    dtype = jnp.result_type(float)
    t0 = jnp.asarray(t0, dtype=dtype)
    t1 = jnp.asarray(t1, dtype=dtype)
    direction = jnp.sign(t1 - t0)
    t_res = _time_resolution(t0, t1, dtype)
    err_exp = -1.0 / (tab.err_order + 1.0)
    combiner = get_combiner(tab, combine_backend)

    zeros_like_buf = jax.tree_util.tree_map(
        lambda l: jnp.zeros((cfg.max_steps,) + l.shape, l.dtype), x0)
    ts_buf = jnp.zeros((cfg.max_steps,), dtype)
    hs_buf = jnp.zeros((cfg.max_steps,), dtype)

    def cond(state):
        (t, x, h, n_acc, n_try, xs, ts, hs, fe) = state
        # non-finite h means the solve is already dead (a NaN state or field
        # NaNs the error norm, the rejection then NaNs the h carry): bail
        # instead of burning max_attempts identical doomed trials — e.g.
        # when a later SaveAt segment starts from a poisoned on_failure
        # state.  Exiting short of t1 leaves succeeded=False as usual.
        return (direction * (t1 - t) > t_res) \
            & (n_acc < cfg.max_steps) & (n_try < cfg.max_attempts) \
            & jnp.isfinite(h)

    def body(state):
        (t, x, h, n_acc, n_try, xs, ts, hs, fe) = state
        # clamp the TRIAL step so we land exactly on t1; the carried h
        # stays unclamped (see the docstring).
        clamped = jnp.abs(h) > jnp.abs(t1 - t)
        h_eff = direction * jnp.minimum(jnp.abs(h), jnp.abs(t1 - t))
        x_next, err = rk_step(f, tab, x, t, h_eff, params, combiner,
                              with_error=True)
        enorm = _error_norm(err, x, x_next, cfg.rtol, cfg.atol)
        accept = enorm <= 1.0
        factor = jnp.clip(cfg.safety * jnp.power(jnp.maximum(enorm, 1e-10),
                                                 err_exp),
                          cfg.min_factor, cfg.max_factor)
        # clamped landing steps never contaminate the carried step: an
        # ACCEPTED one keeps the natural h, a REJECTED one shrinks from the
        # unclamped h (not from h_eff, which is the t1 gap, not the
        # controller's step — shrinking from it collapses the carry exactly
        # like the accepted case fixed earlier).  Progress is still
        # guaranteed: factor < 1 on every rejection, so h decays
        # geometrically until the trial is no longer clamped — at the cost
        # of up to ceil(log(|h|/gap)/log(1/factor)) re-attempts of the
        # identical clamped trial while |h·factor^k| still exceeds the gap
        # (bounded, and only on the rare rejected-landing path; preserving
        # the carry for the continuation is worth it).  For unclamped
        # trials h_eff == h, so both arms of the old update coincide there.
        h_new = jnp.where(accept & clamped, h, h * factor)

        def commit(bufs):
            xs_b, ts_b, hs_b = bufs
            xs_b = jax.tree_util.tree_map(
                lambda buf, val: jax.lax.dynamic_update_index_in_dim(
                    buf, val.astype(buf.dtype), n_acc, 0), xs_b, x)
            ts_b = jax.lax.dynamic_update_index_in_dim(ts_b, t, n_acc, 0)
            hs_b = jax.lax.dynamic_update_index_in_dim(hs_b, h_eff, n_acc, 0)
            return xs_b, ts_b, hs_b

        xs, ts, hs = jax.lax.cond(accept, commit, lambda bufs: bufs,
                                  (xs, ts, hs))
        t = jnp.where(accept, t + h_eff, t)
        x = jax.tree_util.tree_map(
            lambda a, b: jnp.where(accept, b, a), x, x_next)
        n_acc = n_acc + accept.astype(jnp.int32)
        fevals = tab.s + (1 if tab.err_uses_fsal else 0)
        return (t, x, h_new, n_acc, n_try + 1, xs, ts, hs, fe + fevals)

    h0_abs = jnp.abs(jnp.asarray(cfg.initial_step if h0 is None else h0,
                                 dtype))
    h_init = direction * jnp.where(h0_abs > 0, h0_abs,
                                   jnp.asarray(cfg.initial_step, dtype))
    state0 = (t0, x0, h_init, jnp.int32(0), jnp.int32(0),
              zeros_like_buf, ts_buf, hs_buf, jnp.int32(0))
    (t, x, h, n_acc, n_try, xs, ts, hs, fe) = jax.lax.while_loop(
        cond, body, state0)
    succeeded = jnp.logical_not(direction * (t1 - t) > t_res)
    return AdaptiveSolution(x, xs, ts, hs, n_acc, fe, succeeded, h, n_try)


def _error_norm_lanes(err, x, x_next, rtol, atol):
    """Per-lane error norms for lane-batched states (lane axis 0 per leaf).

    This is ``jax.vmap`` of ``_error_norm`` itself, NOT a reimplementation:
    each lane's norm applies the identical per-leaf elementwise scale
    ``atol + rtol * max(|x|, |x_next|)`` and the identical element-count
    weighting across mixed-magnitude leaves as a single-trajectory solve of
    that lane — so masked per-lane step control accepts exactly the steps a
    loop of single solves would (tests/test_batch.py pins this for
    mixed-magnitude pytree states).  Returns shape (B,).
    """
    return jax.vmap(
        lambda e, a, b: _error_norm(e, a, b, rtol, atol))(err, x, x_next)


def _raise_on_failure_cb(ok):
    if not bool(ok):
        raise RuntimeError(
            "odeint: adaptive solver exhausted max_steps/max_attempts "
            "without reaching t1 (AdaptiveConfig(on_failure='raise'))")


def lane_bcast(v, leaf):
    """Broadcast a per-lane vector (B,) against a lane-batched leaf (B, ...).

    Also the degenerate scalar case: a () ``v`` reshapes to all-singleton
    dims, so one code path serves batched and unbatched policies."""
    return jnp.reshape(v, jnp.shape(v) + (1,) * (jnp.ndim(leaf) - 1))


def apply_on_failure(x_final: Pytree, succeeded, on_failure: str) -> Pytree:
    """Apply an AdaptiveConfig.on_failure policy to a solver result.

    ``succeeded`` may be a scalar (one trajectory) or a per-lane (B,)
    vector (``batch_axis=0`` — lane axis 0 of every leaf): "nan" poisons
    exactly the failed trajectories, "raise" raises when any failed.
    """
    if on_failure == "ignore":
        return x_final
    if on_failure == "raise":
        jax.debug.callback(_raise_on_failure_cb, jnp.all(succeeded))
        return x_final
    assert on_failure == "nan", on_failure

    def poison(l):
        if not jnp.issubdtype(l.dtype, jnp.inexact):
            return l
        return jnp.where(lane_bcast(succeeded, l), l,
                         jnp.full_like(l, jnp.nan))

    return jax.tree_util.tree_map(poison, x_final)


def lane_count(x0: Pytree) -> int:
    """Lane count B of a lane-batched state: every leaf must carry the same
    leading lane axis (``solve(..., batch_axis=0)``)."""
    leaves = jax.tree_util.tree_leaves(x0)
    if not leaves:
        raise ValueError("batched solve needs a non-empty state pytree")
    sizes = set()
    for l in leaves:
        if jnp.ndim(l) < 1:
            raise ValueError(
                "batch_axis=0 requires every state leaf to carry a leading "
                f"lane axis; got a rank-0 leaf {l!r}")
        sizes.add(jnp.shape(l)[0])
    if len(sizes) != 1:
        raise ValueError(
            "batch_axis=0 requires every state leaf to share the same "
            f"leading lane-axis size; got sizes {sorted(sizes)}")
    return sizes.pop()


# Named alias for the per-lane reading at batched call sites; the policy
# logic lives once in apply_on_failure (lane_bcast handles both ranks).
apply_on_failure_lanes = apply_on_failure


# ---------------------------------------------------------------------------
# Batch-native adaptive stepping: one while_loop, masked per-lane control.
# ---------------------------------------------------------------------------

class BatchedAdaptiveSolution(NamedTuple):
    """Per-lane results of a batch-native adaptive solve (lane count B).

    The checkpoint buffers keep the step axis LEADING — ``xs`` leaves are
    (max_steps, B, ...), ``ts``/``hs`` are (max_steps, B) — so the
    symplectic backward pass scans step rows exactly like the unbatched
    driver, masking each lane by its own ``n_accepted``.
    """
    x_final: Pytree          # per-lane final states (lane axis 0)
    xs: Pytree               # (max_steps, B, ...) accepted checkpoints
    ts: jnp.ndarray          # (max_steps, B)
    hs: jnp.ndarray          # (max_steps, B)
    n_accepted: jnp.ndarray  # (B,) int32
    n_fevals: jnp.ndarray    # (B,) int32: per-lane f evaluations
    succeeded: jnp.ndarray   # (B,) bool: lane reached t1 within budgets
    h_final: jnp.ndarray     # (B,) unclamped controller step at lane exit
    n_attempts: jnp.ndarray  # (B,) int32: per-lane trial steps (acc + rej)


def rk_solve_adaptive_batched(f: VectorField, tab: ButcherTableau, x0,
                              t0, t1, params, cfg: AdaptiveConfig,
                              combine_backend: str = "auto",
                              h0=None) -> BatchedAdaptiveSolution:
    """Adaptive solve of B independent trajectories in ONE while_loop.

    ``x0`` is lane-batched (lane axis 0 of every leaf).  Each lane carries
    its own ``(t, h, n_accepted, n_attempts)`` controller state, its own
    error norm (``_error_norm_lanes``: the single-trajectory norm per lane,
    never pooled across the batch), and its own accept/reject decision —
    finished and rejected lanes are masked on commit, so no lane's
    stiffness can perturb another lane's accepted grid.  The loop runs
    until every lane lands (or exhausts its budgets), and each trial step
    evaluates ``f`` ONCE over the full batch (the stage combines stay fused
    through the StageCombiner under ``vmap``), so the hot path keeps its
    batched shape; iterations where some lanes are already done spend
    wasted lane-slots, which is the price of the fused evaluation
    (docs/batching.md quantifies the trade against lockstep batch-in-state
    solving).

    Every controller rule matches ``rk_solve_adaptive`` per lane — the
    unclamped-h carry for landing steps, the dtype-aware termination
    threshold, the PI factor — so lane b of the result is the
    single-trajectory solve of lane b to rounding (tests/test_batch.py).
    ``t0``/``t1``/``h0`` may be scalars (shared) or (B,) per-lane arrays.
    """
    if tab.b_err is None:
        raise ValueError(f"tableau {tab.name} has no embedded error estimate")
    B = lane_count(x0)
    dtype = jnp.result_type(float)
    t0 = jnp.broadcast_to(jnp.asarray(t0, dtype=dtype), (B,))
    t1 = jnp.broadcast_to(jnp.asarray(t1, dtype=dtype), (B,))
    direction = jnp.sign(t1 - t0)
    t_res = _time_resolution(t0, t1, dtype)
    err_exp = -1.0 / (tab.err_order + 1.0)
    combiner = get_combiner(tab, combine_backend)

    step_lanes = jax.vmap(
        lambda x_l, t_l, h_l: rk_step(f, tab, x_l, t_l, h_l, params,
                                      combiner, with_error=True))

    zeros_like_buf = jax.tree_util.tree_map(
        lambda l: jnp.zeros((cfg.max_steps,) + l.shape, l.dtype), x0)
    ts_buf = jnp.zeros((cfg.max_steps, B), dtype)
    hs_buf = jnp.zeros((cfg.max_steps, B), dtype)

    def _commit_lane(col, val, idx, do):
        # col: ONE lane's (max_steps, ...) buffer column.  Touch only row
        # idx (read-select-write), so a trial step costs O(state) per lane,
        # not an O(max_steps * state) whole-buffer select.
        cur = jax.lax.dynamic_index_in_dim(col, idx, 0, keepdims=False)
        new = jnp.where(do, val.astype(col.dtype), cur)
        return jax.lax.dynamic_update_index_in_dim(col, new, idx, 0)

    commit = jax.vmap(_commit_lane, in_axes=(1, 0, 0, 0), out_axes=1)

    def lanes_active(t, n_acc, n_try, h):
        # the isfinite(h) bail mirrors the single driver: a lane whose
        # state went NaN (e.g. poisoned by on_failure in an earlier SaveAt
        # segment) NaNs its h carry on the first rejected trial and drops
        # out of the batch one iteration later, instead of pinning every
        # healthy lane behind max_attempts doomed full-batch steps.
        return (direction * (t1 - t) > t_res) \
            & (n_acc < cfg.max_steps) & (n_try < cfg.max_attempts) \
            & jnp.isfinite(h)

    def cond(state):
        (t, x, h, n_acc, n_try, xs, ts, hs, fe) = state
        return jnp.any(lanes_active(t, n_acc, n_try, h))

    def body(state):
        (t, x, h, n_acc, n_try, xs, ts, hs, fe) = state
        active = lanes_active(t, n_acc, n_try, h)
        # per-lane trial clamp; the carried h stays unclamped exactly as in
        # rk_solve_adaptive (accepted clamped landings keep h, rejected
        # ones retry from h * factor).
        clamped = jnp.abs(h) > jnp.abs(t1 - t)
        h_eff = direction * jnp.minimum(jnp.abs(h), jnp.abs(t1 - t))
        x_next, err = step_lanes(x, t, h_eff)
        enorm = _error_norm_lanes(err, x, x_next, cfg.rtol, cfg.atol)
        accept = enorm <= 1.0
        factor = jnp.clip(cfg.safety * jnp.power(jnp.maximum(enorm, 1e-10),
                                                 err_exp),
                          cfg.min_factor, cfg.max_factor)
        h_new = jnp.where(accept & clamped, h, h * factor)
        h = jnp.where(active, h_new, h)      # done lanes freeze their carry
        do = active & accept
        xs = jax.tree_util.tree_map(
            lambda buf, val: commit(buf, val, n_acc, do), xs, x)
        ts = commit(ts, t, n_acc, do)
        hs = commit(hs, h_eff, n_acc, do)
        t = jnp.where(do, t + h_eff, t)
        x = jax.tree_util.tree_map(
            lambda a, b: jnp.where(lane_bcast(do, a), b, a), x, x_next)
        n_acc = n_acc + do.astype(jnp.int32)
        n_try = n_try + active.astype(jnp.int32)
        fevals = tab.s + (1 if tab.err_uses_fsal else 0)
        fe = fe + active.astype(jnp.int32) * fevals
        return (t, x, h, n_acc, n_try, xs, ts, hs, fe)

    h0_abs = jnp.abs(jnp.broadcast_to(
        jnp.asarray(cfg.initial_step if h0 is None else h0, dtype), (B,)))
    h_init = direction * jnp.where(h0_abs > 0, h0_abs,
                                   jnp.asarray(cfg.initial_step, dtype))
    lane_i32 = jnp.zeros((B,), jnp.int32)
    state0 = (t0, x0, h_init, lane_i32, lane_i32,
              zeros_like_buf, ts_buf, hs_buf, lane_i32)
    (t, x, h, n_acc, n_try, xs, ts, hs, fe) = jax.lax.while_loop(
        cond, body, state0)
    succeeded = jnp.logical_not(direction * (t1 - t) > t_res)
    return BatchedAdaptiveSolution(x, xs, ts, hs, n_acc, fe, succeeded,
                                   h, n_try)


def rk_solve_adaptive_batched_saveat_stacked(
        f: VectorField, tab: ButcherTableau, x0, t0, ts: jnp.ndarray,
        params, cfg: AdaptiveConfig, combine_backend: str = "auto"):
    """Batched analogue of ``rk_solve_adaptive_saveat_stacked``: one scanned
    segment chain, per-lane controller state ``(x, h_final)`` threading
    across every observation boundary (each lane's landing step stays
    unclamped in ITS carry).  Observation times are shared across lanes.
    A lane whose segment fails is poisoned per ``cfg.on_failure`` without
    touching its batchmates, and the poison propagates to that lane's later
    observations.  Returns (obs, sols) with a leading len(ts) segment axis
    on every ``BatchedAdaptiveSolution`` field.
    """
    dtype = jnp.result_type(float)
    ts = jnp.asarray(ts, dtype)
    B = lane_count(x0)
    t_starts = segment_starts(t0, ts)

    def body(carry, seg):
        x, h = carry
        a, b = seg
        sol = rk_solve_adaptive_batched(f, tab, x, a, b, params, cfg,
                                        combine_backend, h0=h)
        x = apply_on_failure_lanes(sol.x_final, sol.succeeded,
                                   cfg.on_failure)
        sol = sol._replace(x_final=x)
        return (x, sol.h_final), sol

    _, sols = jax.lax.scan(body, (x0, jnp.zeros((B,), dtype)),
                           (t_starts, ts))
    return sols.x_final, sols


# ---------------------------------------------------------------------------
# SaveAt support: segmented adaptive solves + Hermite dense output.
# ---------------------------------------------------------------------------

def segment_starts(t0, ts: jnp.ndarray) -> jnp.ndarray:
    """Left endpoints of the observation segments: [t0, ts[0], ..., ts[-2]].

    Zipped with ``ts`` these are the (start, end) pairs every scanned
    SaveAt driver iterates over.
    """
    t0 = jnp.reshape(jnp.asarray(t0, ts.dtype), (1,))
    return jnp.concatenate([t0, ts[:-1]])


def rk_solve_adaptive_saveat_stacked(f: VectorField, tab: ButcherTableau,
                                     x0, t0, ts: jnp.ndarray, params,
                                     cfg: AdaptiveConfig,
                                     combine_backend: str = "auto"):
    """Adaptive solve observed at the times ``ts`` by segmenting the solve.

    One adaptive sub-solve per segment [t0, ts[0]], [ts[0], ts[1]], ...; the
    controller state threads across segments (each segment seeds its step
    from the previous segment's unclamped ``h_final``, so landing exactly on
    an observation time costs one clamped step, not a collapsed restart).
    A failed segment poisons its state per ``cfg.on_failure`` and the
    poison propagates to every later observation.

    The segments run inside ONE ``lax.scan`` (every segment shares the
    ``max_steps`` buffer bound, so shapes are uniform): trace size, jaxpr
    size, and compile time are O(1) in len(ts).

    Returns (obs, sols): ``obs`` the stacked observations (leading dim
    len(ts)), ``sols`` an AdaptiveSolution whose every field carries a
    leading len(ts) segment axis.
    """
    dtype = jnp.result_type(float)
    ts = jnp.asarray(ts, dtype)
    t_starts = segment_starts(t0, ts)

    def body(carry, seg):
        x, h = carry
        a, b = seg
        sol = rk_solve_adaptive(f, tab, x, a, b, params, cfg,
                                combine_backend, h0=h)
        x = apply_on_failure(sol.x_final, sol.succeeded, cfg.on_failure)
        sol = sol._replace(x_final=x)
        return (x, sol.h_final), sol

    # h0 = 0 makes the first segment fall back to cfg.initial_step.
    _, sols = jax.lax.scan(body, (x0, jnp.zeros((), dtype)),
                           (t_starts, ts))
    return sols.x_final, sols


def rk_solve_adaptive_saveat(f: VectorField, tab: ButcherTableau, x0, t0,
                             ts: jnp.ndarray, params, cfg: AdaptiveConfig,
                             combine_backend: str = "auto"):
    """List-of-segments convenience wrapper around the scanned driver.

    Returns (obs, sols) with ``sols`` a Python list of per-segment
    AdaptiveSolutions (unstacked views into the scanned buffers).  Solver
    hot paths use ``rk_solve_adaptive_saveat_stacked`` directly — the
    unstacking here costs O(len(ts)) trace equations and is meant for
    inspection and tests.
    """
    obs, stacked = rk_solve_adaptive_saveat_stacked(
        f, tab, x0, t0, ts, params, cfg, combine_backend)
    sols = [jax.tree_util.tree_map(lambda l: l[i], stacked)
            for i in range(ts.shape[0])]
    return obs, sols


def hermite_observe(f: VectorField, tab: ButcherTableau,
                    sol: AdaptiveSolution, params, taus: jnp.ndarray,
                    combine_backend: str = "auto") -> Pytree:
    """Dense-output observation of ONE adaptive solve at the times ``taus``.

    4th-order cubic-Hermite interpolation over the accepted step containing
    each tau (StageCombiner.interpolate — the same row-combine primitive as
    the Butcher rows).  The step endpoints come from the checkpoint buffer;
    their slopes are recomputed (2 extra f-evals per observation), so the
    step controller is never perturbed by observation times.  taus outside
    the integrated span clamp to the nearest endpoint.
    """
    combiner = get_combiner(tab, combine_backend)
    max_steps = sol.ts.shape[0]
    n_acc = sol.n_accepted
    last = jnp.maximum(n_acc - 1, 0)
    direction = jnp.sign(jnp.where(n_acc > 0, sol.hs[0], 1.0))
    valid = jnp.arange(max_steps) < n_acc
    keys = jnp.where(valid, direction * sol.ts, jnp.inf)

    def observe_one(tau):
        n = jnp.clip(jnp.searchsorted(keys, direction * tau,
                                      side="right") - 1, 0, last)
        t_n = sol.ts[n]
        h_n = sol.hs[n]
        x_n = jax.tree_util.tree_map(
            lambda b: jax.lax.dynamic_index_in_dim(b, n, 0, keepdims=False),
            sol.xs)
        # x_{n+1}: next checkpoint, or x_final for the last accepted step.
        is_last = n >= n_acc - 1
        x_n1 = jax.tree_util.tree_map(
            lambda b, xf: jnp.where(
                is_last, xf,
                jax.lax.dynamic_index_in_dim(
                    b, jnp.minimum(n + 1, max_steps - 1), 0,
                    keepdims=False)),
            sol.xs, sol.x_final)
        theta = jnp.clip((tau - t_n) / jnp.where(h_n == 0, 1.0, h_n),
                         0.0, 1.0)
        f0 = f(x_n, t_n, params)
        f1 = f(x_n1, t_n + h_n, params)
        out = combiner.interpolate(x_n, x_n1, f0, f1, h_n, theta)
        # degenerate solve (no accepted steps): the state never moved.
        return jax.tree_util.tree_map(
            lambda o, xf: jnp.where(n_acc > 0, o, xf), out, sol.x_final)

    # observe_one is elementwise in tau: ONE traced copy serves every
    # observation (and slope recomputations batch), instead of unrolling
    # the search + interpolate + 2-f-eval graph per tau.
    return jax.vmap(observe_one)(taus)
