"""Explicit Runge-Kutta integration over arbitrary pytree states.

Two drivers:
  * ``rk_solve_fixed``    — N equal steps via lax.scan (deterministic shape;
                            used by the LM node_mode and all dry-run cells).
  * ``rk_solve_adaptive`` — PI-controlled adaptive stepping via lax.while_loop
                            with a bounded ``max_steps`` checkpoint buffer
                            (used by the CNF / physics experiments, mirroring
                            the paper's dopri5-adaptive setting).

Both record the step checkpoints {x_n, t_n, h_n} that Algorithm 1 of the paper
retains; computation graphs are never part of the residuals (the gradient
modes in odeint.py decide what autodiff sees).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from .tableau import ButcherTableau

Pytree = Any
VectorField = Callable[[Pytree, jnp.ndarray, Pytree], Pytree]
# f(x, t, params) -> dx/dt, pytree-in pytree-out.


def tree_scale_add(base: Pytree, terms) -> Pytree:
    """base + sum_i coef_i * tree_i, fused per leaf.

    ``terms`` is a list of (coef, tree). Zero coefficients (python floats)
    are dropped at trace time, so explicit tableaus pay only for their
    nonzero entries.
    """
    terms = [(c, t) for (c, t) in terms
             if not (isinstance(c, float) and c == 0.0)]
    if not terms:
        return base
    leaves_b, treedef = jax.tree_util.tree_flatten(base)
    term_leaves = [jax.tree_util.tree_flatten(t)[0] for _, t in terms]
    coefs = [c for c, _ in terms]
    out = []
    for idx, lb in enumerate(leaves_b):
        acc = lb
        for c, leaves in zip(coefs, term_leaves):
            acc = acc + jnp.asarray(c, dtype=lb.dtype) * leaves[idx]
        out.append(acc)
    return jax.tree_util.tree_unflatten(treedef, out)


def rk_stages(f: VectorField, tab: ButcherTableau, x, t, h, params):
    """Compute all stage states X_i and slopes k_i for one step.

    Returns (Xs, ks) as lists of pytrees, length s. Purely forward; the
    symplectic backward pass re-runs this from a checkpoint (Alg. 2 lines 3-7).
    """
    s = tab.s
    Xs, ks = [], []
    for i in range(s):
        Xi = tree_scale_add(
            x, [(tab.a[i][j], _hk(h, ks[j])) for j in range(i)])
        ki = f(Xi, t + tab.c[i] * h, params)
        Xs.append(Xi)
        ks.append(ki)
    return Xs, ks


def _hk(h, k):
    # cast h into each leaf dtype so mixed-precision states keep their dtype
    return jax.tree_util.tree_map(
        lambda l: jnp.asarray(h, dtype=l.dtype) * l, k)


def rk_step(f: VectorField, tab: ButcherTableau, x, t, h, params):
    """One explicit RK step: returns (x_next, err_estimate_or_None)."""
    Xs, ks = rk_stages(f, tab, x, t, h, params)
    x_next = tree_scale_add(
        x, [(tab.b[i], _hk(h, ks[i])) for i in range(tab.s)])
    err = None
    if tab.b_err is not None:
        ks_err = list(ks)
        if tab.err_uses_fsal:
            ks_err.append(f(x_next, t + h, params))
        err = tree_scale_add(
            jax.tree_util.tree_map(jnp.zeros_like, x),
            [(tab.b_err[i], _hk(h, ks_err[i])) for i in range(len(ks_err))])
    return x_next, err


class FixedSolution(NamedTuple):
    x_final: Pytree
    xs: Pytree          # stacked checkpoints x_0..x_{N-1} (leading dim N)
    ts: jnp.ndarray     # t_0..t_{N-1}
    h: jnp.ndarray      # scalar step size


def rk_solve_fixed(f: VectorField, tab: ButcherTableau, x0, t0, t1,
                   n_steps: int, params) -> FixedSolution:
    t0 = jnp.asarray(t0, dtype=jnp.result_type(float))
    t1 = jnp.asarray(t1, dtype=t0.dtype)
    h = (t1 - t0) / n_steps

    def body(carry, n):
        x, = carry
        t = t0 + n.astype(t0.dtype) * h
        x_next, _ = rk_step(f, tab, x, t, h, params)
        return (x_next,), (x, t)

    (xf,), (xs, ts) = jax.lax.scan(body, (x0,), jnp.arange(n_steps))
    return FixedSolution(xf, xs, ts, h)


# ---------------------------------------------------------------------------
# Adaptive stepping (PI controller), bounded buffer of accepted checkpoints.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    rtol: float = 1e-6
    atol: float = 1e-8
    max_steps: int = 256          # checkpoint buffer bound (accepted steps)
    max_attempts: int = 4096      # total trial-step bound
    safety: float = 0.9
    min_factor: float = 0.2
    max_factor: float = 10.0
    initial_step: float = 0.01


class AdaptiveSolution(NamedTuple):
    x_final: Pytree
    xs: Pytree           # (max_steps, ...) accepted checkpoints, zero-padded
    ts: jnp.ndarray      # (max_steps,)
    hs: jnp.ndarray      # (max_steps,)
    n_accepted: jnp.ndarray  # int32 scalar
    n_fevals: jnp.ndarray    # int32 scalar


def _error_norm(err, x, x_next, rtol, atol):
    leaves = zip(jax.tree_util.tree_leaves(err),
                 jax.tree_util.tree_leaves(x),
                 jax.tree_util.tree_leaves(x_next))
    total, count = 0.0, 0
    for e, a, b in leaves:
        scale = atol + rtol * jnp.maximum(jnp.abs(a), jnp.abs(b))
        r = (e / scale).astype(jnp.float32)
        total = total + jnp.sum(r * r)
        count += r.size
    return jnp.sqrt(total / count)


def rk_solve_adaptive(f: VectorField, tab: ButcherTableau, x0, t0, t1,
                      params, cfg: AdaptiveConfig) -> AdaptiveSolution:
    if tab.b_err is None:
        raise ValueError(f"tableau {tab.name} has no embedded error estimate")
    dtype = jnp.result_type(float)
    t0 = jnp.asarray(t0, dtype=dtype)
    t1 = jnp.asarray(t1, dtype=dtype)
    direction = jnp.sign(t1 - t0)
    err_exp = -1.0 / (tab.err_order + 1.0)

    zeros_like_buf = jax.tree_util.tree_map(
        lambda l: jnp.zeros((cfg.max_steps,) + l.shape, l.dtype), x0)
    ts_buf = jnp.zeros((cfg.max_steps,), dtype)
    hs_buf = jnp.zeros((cfg.max_steps,), dtype)

    def cond(state):
        (t, x, h, n_acc, n_try, xs, ts, hs, fe) = state
        return (direction * (t1 - t) > 1e-14) \
            & (n_acc < cfg.max_steps) & (n_try < cfg.max_attempts)

    def body(state):
        (t, x, h, n_acc, n_try, xs, ts, hs, fe) = state
        # clamp the step so we land exactly on t1
        h_eff = direction * jnp.minimum(jnp.abs(h), jnp.abs(t1 - t))
        x_next, err = rk_step(f, tab, x, t, h_eff, params)
        enorm = _error_norm(err, x, x_next, cfg.rtol, cfg.atol)
        accept = enorm <= 1.0
        factor = jnp.clip(cfg.safety * jnp.power(jnp.maximum(enorm, 1e-10),
                                                 err_exp),
                          cfg.min_factor, cfg.max_factor)
        h_new = h_eff * factor

        xs = jax.tree_util.tree_map(
            lambda buf, val: jax.lax.cond(
                accept,
                lambda: jax.lax.dynamic_update_index_in_dim(
                    buf, val.astype(buf.dtype), n_acc, 0),
                lambda: buf),
            xs, x)
        ts = jax.lax.cond(
            accept,
            lambda: jax.lax.dynamic_update_index_in_dim(ts_buf_like(ts), t,
                                                        n_acc, 0),
            lambda: ts)
        hs = jax.lax.cond(
            accept,
            lambda: jax.lax.dynamic_update_index_in_dim(ts_buf_like(hs),
                                                        h_eff, n_acc, 0),
            lambda: hs)
        t = jnp.where(accept, t + h_eff, t)
        x = jax.tree_util.tree_map(
            lambda a, b: jnp.where(accept, b, a), x, x_next)
        n_acc = n_acc + accept.astype(jnp.int32)
        fevals = tab.s + (1 if tab.err_uses_fsal else 0)
        return (t, x, h_new, n_acc, n_try + 1, xs, ts, hs, fe + fevals)

    def ts_buf_like(b):
        return b

    h0 = direction * jnp.asarray(cfg.initial_step, dtype)
    state0 = (t0, x0, h0, jnp.int32(0), jnp.int32(0),
              zeros_like_buf, ts_buf, hs_buf, jnp.int32(0))
    (t, x, h, n_acc, n_try, xs, ts, hs, fe) = jax.lax.while_loop(
        cond, body, state0)
    return AdaptiveSolution(x, xs, ts, hs, n_acc, fe)
