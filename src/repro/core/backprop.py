"""Differentiate-through-the-solver gradient modes (the paper's baselines).

  * ``backprop``     — plain jax.grad through the scan; XLA retains every
                       stage activation: memory O(M N s L)  (paper's "naive
                       backpropagation").
  * ``remat_step``   — jax.checkpoint around each RK step: scan saves the step
                       carries {x_n} and rematerializes one step's s-stage
                       graph during backward: memory O(M N + s L) — the
                       ANODE/ACA checkpointing scheme.
  * ``remat_solve``  — jax.checkpoint around the whole component solve with
                       nothing saved: re-runs the forward once inside the
                       backward and then backprops it: memory O(M + N s L) —
                       the paper's "baseline scheme".

All three route stage combination through the StageCombiner; the Pallas
backend stays differentiable via the custom-JVP wrappers in core/combine.py.
"""
from __future__ import annotations

import functools
from typing import Any

import jax

from .combine import get_combiner
from .rk import VectorField, rk_solve_fixed, rk_step
from .tableau import ButcherTableau

Pytree = Any


def odeint_backprop(f: VectorField, tab: ButcherTableau, n_steps: int,
                    x0, t0, t1, params, combine_backend: str = "auto"):
    return rk_solve_fixed(f, tab, x0, t0, t1, n_steps, params,
                          combine_backend).x_final


def odeint_remat_step(f: VectorField, tab: ButcherTableau, n_steps: int,
                      x0, t0, t1, params, combine_backend: str = "auto"):
    import jax.numpy as jnp
    t0 = jnp.asarray(t0, dtype=jnp.result_type(float))
    t1 = jnp.asarray(t1, dtype=t0.dtype)
    h = (t1 - t0) / n_steps
    combiner = get_combiner(tab, combine_backend)

    @jax.checkpoint
    def step(x, t, params):
        x_next, _ = rk_step(f, tab, x, t, h, params, combiner,
                            with_error=False)
        return x_next

    def body(x, n):
        t = t0 + n.astype(t0.dtype) * h
        return step(x, t, params), None

    xf, _ = jax.lax.scan(body, x0, jnp.arange(n_steps))
    return xf


def odeint_remat_solve(f: VectorField, tab: ButcherTableau, n_steps: int,
                       x0, t0, t1, params, combine_backend: str = "auto"):
    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def solve(x0, params):
        return rk_solve_fixed(f, tab, x0, t0, t1, n_steps, params,
                              combine_backend).x_final

    return solve(x0, params)
