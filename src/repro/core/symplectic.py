"""The symplectic adjoint method (the paper's contribution).

Forward (Algorithm 1): integrate with any explicit Runge-Kutta tableau,
retaining ONLY the step checkpoints {x_n, t_n, h_n} — these become the
custom_vjp residuals, so no stage computation graph survives the forward pass.

Backward (Algorithm 2 + Eq. (7)/(8)): for each step n = N-1..0,
  1. recompute the stage states X_{n,i} from the checkpoint x_n (lines 3-7),
  2. run the symplectic-partner stage recursion i = s..1 (lines 8-13):

        Lambda_{n,i} = lambda_{n+1} - h * sum_{j>i} btilde_j (a_{j,i}/b_i) l_j   (i not in I0)
        Lambda_{n,i} = - sum_{j>i} btilde_j a_{j,i} l_j                          (i in I0)
        l_{n,i}      = -(df/dx(X_{n,i}))^T Lambda_{n,i}
        btilde_i     = b_i  (i not in I0),   h_n  (i in I0 = {i: b_i = 0})

     each l_{n,i} is ONE jax.vjp of ONE network evaluation, and
  3. lambda_n = lambda_{n+1} - h * sum_i btilde_i l_{n,i};
     grad_theta += h * sum_i btilde_i (df/dtheta(X_{n,i}))^T Lambda_{n,i}.

Because the partitioned pair (forward RK, Eq. (7)) is symplectic, the bilinear
invariant lambda^T delta is conserved exactly in discrete time (Theorem 2), so
lambda_0 equals the EXACT gradient of the discrete forward map — verified
against jax.grad-through-the-solver to rounding error in tests.

The adjoint slopes l_{n,i} live in a stacked buffer (leading stage dim per
leaf), and both the Lambda recursion and the lambda_n update are row combines
through the StageCombiner (core/combine.py) — the same fused one-HBM-pass
primitive (jnp oracle or Pallas kernel) the forward solve uses, with the
h-dependent Eq. (7)/(8) coefficient rows precomputed per tableau.

Memory note (the paper's point, realized in XLA dataflow): the stage-i VJP's
residuals are forced to be live one-at-a-time by threading the previous
adjoint slope through ``lax.optimization_barrier`` into the stage state, so
neither CSE nor the scheduler can hoist all s recomputation graphs at once.
Live memory is O(N + s + L), not O(N * s * L).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .combine import StageCombiner, alloc_stages, get_combiner, set_stage
from .rk import (AdaptiveConfig, VectorField, apply_on_failure,
                 apply_on_failure_lanes, lane_bcast, rk_solve_adaptive,
                 rk_solve_adaptive_batched,
                 rk_solve_adaptive_batched_saveat_stacked,
                 rk_solve_adaptive_saveat_stacked, rk_solve_fixed, rk_stages,
                 segment_starts, time_lift as _lift,
                 time_unlift as _unlift,
                 time_zero_cotangent as _time_zero)
from .tableau import ButcherTableau

Pytree = Any


def _tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def _tree_zeros(t):
    return jax.tree_util.tree_map(jnp.zeros_like, t)


def _barrier_with(x: Pytree, dep: Pytree) -> Pytree:
    """Return x, data-dependent on dep, opaque to CSE/scheduling."""
    leaves, treedef = jax.tree_util.tree_flatten((x, dep))
    leaves = jax.lax.optimization_barrier(leaves)
    x_out, _ = jax.tree_util.tree_unflatten(treedef, leaves)
    return x_out


def symplectic_step_adjoint(f: VectorField, tab: ButcherTableau,
                            x_n, t_n, h, params, lam_next,
                            combiner: Optional[StageCombiner] = None):
    """One backward step of Algorithm 2. Returns (lambda_n, grad_theta_step)."""
    combiner = combiner or get_combiner(tab)
    s = tab.s
    b, c = tab.b, tab.c
    # --- Alg.2 lines 3-7: recompute stages from the checkpoint ----------
    Xs, _K = rk_stages(f, tab, x_n, t_n, h, params, combiner)

    def btilde(i):
        # Eq. (8): h_n replaces vanishing weights.
        return h if b[i] == 0.0 else b[i]

    L = alloc_stages(s, lam_next)   # stacked adjoint slopes l_{n,i}
    gtheta = None
    dep = lam_next  # scheduling dependency chain (see module docstring)
    for i in reversed(range(s)):
        # --- Eq. (7): Lambda_{n,i} from the slope-buffer suffix L[i+1:] --
        Lam_i = combiner.lambda_stage(lam_next, L, h, i)
        # --- Alg.2 lines 10-12: one VJP of one network evaluation -------
        Xi = _barrier_with(Xs[i], dep)
        t_i = t_n + c[i] * h
        _, vjp_fn = jax.vjp(lambda X, th: f(X, t_i, th), Xi, params)
        xbar, thbar = vjp_fn(Lam_i)
        l_i = jax.tree_util.tree_map(jnp.negative, xbar)
        L = set_stage(L, i, l_i)
        bt_i = btilde(i)
        contrib = jax.tree_util.tree_map(
            lambda g: jnp.asarray(bt_i, dtype=g.dtype) * g, thbar)
        gtheta = contrib if gtheta is None else _tree_add(gtheta, contrib)
        dep = l_i
    # --- lambda_n = lambda_{n+1} - h sum_i btilde_i l_{n,i} --------------
    lam_n = combiner.lambda_update(lam_next, L, h)
    # grad_theta step contribution: + h sum_i btilde_i (df/dtheta)^T Lambda_i
    gtheta = jax.tree_util.tree_map(
        lambda g: jnp.asarray(h, dtype=g.dtype) * g, gtheta)
    return lam_n, gtheta


# ---------------------------------------------------------------------------
# Fixed-grid driver
# ---------------------------------------------------------------------------

# All custom_vjp drivers below take their scalar times as (1,)-shaped
# arrays (see rk.time_lift); the public odeint_* wrappers keep the scalar
# signature and lift at the boundary.

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _odeint_symplectic_r1(f: VectorField, tab: ButcherTableau, n_steps: int,
                          combine_backend: str, x0, t0r, t1r, params):
    sol = rk_solve_fixed(f, tab, x0, _unlift(t0r), _unlift(t1r), n_steps,
                         params,
                         combine_backend)
    return sol.x_final


def odeint_symplectic(f: VectorField, tab: ButcherTableau, n_steps: int,
                      combine_backend: str, x0, t0, t1, params):
    return _odeint_symplectic_r1(f, tab, n_steps, combine_backend,
                                 x0, _lift(t0), _lift(t1), params)


def _sym_fwd(f, tab, n_steps, combine_backend, x0, t0r, t1r, params):
    sol = rk_solve_fixed(f, tab, x0, _unlift(t0r), _unlift(t1r), n_steps,
                         params,
                         combine_backend)
    # Residuals = Algorithm 1's checkpoints (plus the primal times, kept
    # only so the backward pass can emit dtype-matched zero cotangents).
    return sol.x_final, (sol.xs, sol.ts, sol.h, params, t0r, t1r)


def _sym_bwd(f, tab, n_steps, combine_backend, res, lam_N):
    xs, ts, h, params, t0, t1 = res
    combiner = get_combiner(tab, combine_backend)

    def body(carry, inputs):
        lam, gtheta = carry
        x_n, t_n = inputs
        lam, gstep = symplectic_step_adjoint(f, tab, x_n, t_n, h, params,
                                             lam, combiner)
        return (lam, _tree_add(gtheta, gstep)), None

    (lam0, gtheta), _ = jax.lax.scan(body, (lam_N, _tree_zeros(params)),
                                     (xs, ts), reverse=True)
    return (lam0, _time_zero(t0), _time_zero(t1), gtheta)


_odeint_symplectic_r1.defvjp(_sym_fwd, _sym_bwd)


# ---------------------------------------------------------------------------
# Adaptive driver (bounded checkpoint buffer, masked reverse scan)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _odeint_symplectic_adaptive_r1(f: VectorField, tab: ButcherTableau,
                                   cfg: AdaptiveConfig, combine_backend: str,
                                   x0, t0r, t1r, params):
    sol = rk_solve_adaptive(f, tab, x0, _unlift(t0r), _unlift(t1r), params,
                            cfg,
                            combine_backend)
    return apply_on_failure(sol.x_final, sol.succeeded, cfg.on_failure)


def odeint_symplectic_adaptive(f: VectorField, tab: ButcherTableau,
                               cfg: AdaptiveConfig, combine_backend: str,
                               x0, t0, t1, params):
    return _odeint_symplectic_adaptive_r1(f, tab, cfg, combine_backend,
                                          x0, _lift(t0), _lift(t1), params)


def _syma_fwd(f, tab, cfg, combine_backend, x0, t0r, t1r, params):
    sol = rk_solve_adaptive(f, tab, x0, _unlift(t0r), _unlift(t1r), params,
                            cfg,
                            combine_backend)
    res = (sol.xs, sol.ts, sol.hs, sol.n_accepted, params, t0r, t1r)
    x_final = apply_on_failure(sol.x_final, sol.succeeded, cfg.on_failure)
    return x_final, res


def _syma_bwd(f, tab, cfg, combine_backend, res, lam_N):
    xs, ts, hs, n_acc, params, t0, t1 = res
    combiner = get_combiner(tab, combine_backend)

    def body(carry, inputs):
        lam, gtheta = carry
        x_n, t_n, h_n, idx = inputs
        valid = idx < n_acc

        def live(_):
            lam2, gstep = symplectic_step_adjoint(
                f, tab, x_n, t_n, h_n, params, lam, combiner)
            return lam2, _tree_add(gtheta, gstep)

        def dead(_):
            return lam, gtheta

        lam, gtheta = jax.lax.cond(valid, live, dead, None)
        return (lam, gtheta), None

    idxs = jnp.arange(cfg.max_steps)
    (lam0, gtheta), _ = jax.lax.scan(
        body, (lam_N, _tree_zeros(params)), (xs, ts, hs, idxs),
        reverse=True)
    return (lam0, _time_zero(t0), _time_zero(t1), gtheta)


_odeint_symplectic_adaptive_r1.defvjp(_syma_fwd, _syma_bwd)


# ---------------------------------------------------------------------------
# SaveAt drivers: observation at user times ts, exact gradient preserved.
#
# The solve is split into checkpointed segments at the observation times
# (each observation is a segment endpoint, so no interpolation enters the
# differentiated map).  The backward pass walks the segments in reverse;
# each segment is the existing Algorithm 2 scan, and the incoming cotangent
# of observation i is injected into lambda at its segment boundary before
# that segment's scan runs.  Theorem 2 then applies per segment, so the
# full gradient of any loss over the observations is exact to rounding.
#
# Both directions are lax.scans OVER THE SEGMENTS (segments share n_steps /
# max_steps, so shapes are uniform): the forward stacks per-segment
# checkpoint buffers as scan outputs, the backward is a reverse scan whose
# body injects the i-th observation cotangent (an indexed read from the
# stacked obs_bar via the scan's own slicing) and then runs the per-segment
# Algorithm 2 scan.  Trace size, jaxpr size, and compile time are O(1) in
# the number of observations — see docs/adaptive.md.
# ---------------------------------------------------------------------------

def _sym_saveat_solve(f, tab, n_steps, combine_backend, x0, t0r, ts, params):
    """Forward segmented fixed-grid solve; returns (obs, residuals)."""

    def body(x, seg):
        a, b = seg
        sol = rk_solve_fixed(f, tab, x, a, b, n_steps, params,
                             combine_backend)
        return sol.x_final, (sol.x_final, sol.xs, sol.ts, sol.h)

    _, (obs, seg_xs, seg_ts, seg_hs) = jax.lax.scan(
        body, x0, (segment_starts(_unlift(t0r), ts), ts))
    return obs, (seg_xs, seg_ts, seg_hs, params, t0r, ts)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _odeint_symplectic_saveat_r1(f: VectorField, tab: ButcherTableau,
                                 n_steps: int, combine_backend: str,
                                 x0, t0r, ts, params):
    obs, _ = _sym_saveat_solve(f, tab, n_steps, combine_backend,
                               x0, t0r, ts, params)
    return obs


def odeint_symplectic_saveat(f: VectorField, tab: ButcherTableau,
                             n_steps: int, combine_backend: str,
                             x0, t0, ts, params):
    """Fixed-grid solve observed at ts (n_steps per segment).

    Returns the solution stacked over the observation times (leading dim
    len(ts) per leaf).
    """
    return _odeint_symplectic_saveat_r1(f, tab, n_steps, combine_backend,
                                        x0, _lift(t0), ts, params)


def _sym_saveat_fwd(f, tab, n_steps, combine_backend, x0, t0r, ts, params):
    return _sym_saveat_solve(f, tab, n_steps, combine_backend,
                             x0, t0r, ts, params)


def _sym_saveat_bwd(f, tab, n_steps, combine_backend, res, obs_bar):
    xs_all, ts_all, hs_all, params, t0, ts = res
    combiner = get_combiner(tab, combine_backend)
    lam0 = jax.tree_util.tree_map(lambda l: jnp.zeros_like(l[0]), obs_bar)

    def seg_body(carry, seg):
        lam, gtheta = carry
        ob_i, seg_xs, seg_ts, h_seg = seg
        # inject the cotangent arriving at this segment boundary
        lam = _tree_add(lam, ob_i)

        def body(carry_c, inputs):
            lam_c, g_c = carry_c
            x_n, t_n = inputs
            lam_c, gstep = symplectic_step_adjoint(
                f, tab, x_n, t_n, h_seg, params, lam_c, combiner)
            return (lam_c, _tree_add(g_c, gstep)), None

        (lam, gtheta), _ = jax.lax.scan(body, (lam, gtheta),
                                        (seg_xs, seg_ts), reverse=True)
        return (lam, gtheta), None

    (lam, gtheta), _ = jax.lax.scan(
        seg_body, (lam0, _tree_zeros(params)),
        (obs_bar, xs_all, ts_all, hs_all), reverse=True)
    return (lam, _time_zero(t0), _time_zero(ts), gtheta)


_odeint_symplectic_saveat_r1.defvjp(_sym_saveat_fwd, _sym_saveat_bwd)


def _syma_saveat_solve(f, tab, cfg, combine_backend, x0, t0r, ts, params):
    obs, sols = rk_solve_adaptive_saveat_stacked(
        f, tab, x0, _unlift(t0r), ts, params, cfg, combine_backend)
    res = (sols.xs, sols.ts, sols.hs, sols.n_accepted, params, t0r, ts)
    return obs, res


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _odeint_symplectic_saveat_adaptive_r1(f: VectorField,
                                          tab: ButcherTableau,
                                          cfg: AdaptiveConfig,
                                          combine_backend: str,
                                          x0, t0r, ts, params):
    obs, _ = _syma_saveat_solve(f, tab, cfg, combine_backend,
                                x0, t0r, ts, params)
    return obs


def odeint_symplectic_saveat_adaptive(f: VectorField, tab: ButcherTableau,
                                      cfg: AdaptiveConfig,
                                      combine_backend: str,
                                      x0, t0, ts, params):
    """Adaptive solve observed at ts (one adaptive segment per interval).

    The controller threads its unclamped step across segment boundaries
    (rk_solve_adaptive_saveat), so observation times cost one clamped
    landing step each instead of a collapsed restart.  Failed segments
    follow cfg.on_failure.
    """
    return _odeint_symplectic_saveat_adaptive_r1(
        f, tab, cfg, combine_backend, x0, _lift(t0), ts, params)


def _syma_saveat_fwd(f, tab, cfg, combine_backend, x0, t0r, ts, params):
    return _syma_saveat_solve(f, tab, cfg, combine_backend,
                              x0, t0r, ts, params)


def _syma_saveat_bwd(f, tab, cfg, combine_backend, res, obs_bar):
    xs_all, ts_all, hs_all, n_accs, params, t0, ts = res
    combiner = get_combiner(tab, combine_backend)
    lam0 = jax.tree_util.tree_map(lambda l: jnp.zeros_like(l[0]), obs_bar)
    idxs = jnp.arange(cfg.max_steps)

    def seg_body(carry, seg):
        lam, gtheta = carry
        ob_i, seg_xs, seg_ts, seg_hs, n_acc = seg
        lam = _tree_add(lam, ob_i)

        def body(carry_c, inputs):
            lam_c, g_c = carry_c
            x_n, t_n, h_n, idx = inputs
            valid = idx < n_acc

            def live(_):
                lam2, gstep = symplectic_step_adjoint(
                    f, tab, x_n, t_n, h_n, params, lam_c, combiner)
                return lam2, _tree_add(g_c, gstep)

            def dead(_):
                return lam_c, g_c

            out = jax.lax.cond(valid, live, dead, None)
            return out, None

        (lam, gtheta), _ = jax.lax.scan(
            body, (lam, gtheta), (seg_xs, seg_ts, seg_hs, idxs),
            reverse=True)
        return (lam, gtheta), None

    (lam, gtheta), _ = jax.lax.scan(
        seg_body, (lam0, _tree_zeros(params)),
        (obs_bar, xs_all, ts_all, hs_all, n_accs), reverse=True)
    return (lam, _time_zero(t0), _time_zero(ts), gtheta)


_odeint_symplectic_saveat_adaptive_r1.defvjp(_syma_saveat_fwd,
                                             _syma_saveat_bwd)


# ---------------------------------------------------------------------------
# Batch-native adaptive drivers: per-lane accepted grids, exact per lane.
#
# The forward pass is the masked batch-native driver
# (rk_solve_adaptive_batched): each lane realizes ITS OWN accepted step
# sequence.  That sequence is the gradient-defining object of the symplectic
# adjoint, so the backward pass must replay each lane's own grid — the
# reverse scan walks the shared (max_steps, B) checkpoint rows, runs one
# lane-vmapped Algorithm-2 step per row, and masks each lane by its own
# n_accepted: a lane with fewer accepted steps simply carries its lambda
# unchanged through the rows beyond its count.  Theorem 2 then applies per
# lane, so the batched gradient equals the sum of per-lane single-solve
# gradients to rounding (tests/test_batch.py pins it against a Python loop
# of single solves).
# ---------------------------------------------------------------------------

def symplectic_step_adjoint_lanes(f: VectorField, tab: ButcherTableau,
                                  x_n, t_n, h_n, params, lam_next,
                                  combiner: Optional[StageCombiner] = None):
    """One backward Algorithm-2 step for a batch of lanes at once.

    ``x_n``/``lam_next`` are lane-batched (lane axis 0), ``t_n``/``h_n``
    are (B,).  This is the single-lane ``symplectic_step_adjoint`` with the
    per-lane-scalar pieces (stage recomputation, the Eq. (7) Lambda rows,
    one VJP per stage) run under ``jax.vmap`` — NOT a vmap of the whole
    step: ``lax.optimization_barrier`` has no batching rule, so the
    scheduling barrier is applied directly to the lane-batched stage state
    between the vmapped pieces.  The memory discipline is unchanged: one
    stage's (batched) VJP residuals are live at a time.

    Returns (lambda_n, grad_theta_step) with grad_theta_step PER LANE —
    leaves (B,) + param shape — so the caller can mask invalid lanes
    before reducing over the batch.
    """
    combiner = combiner or get_combiner(tab)
    s = tab.s
    b, c = tab.b, tab.c
    # --- Alg.2 lines 3-7: recompute stages from the per-lane checkpoints --
    Xs, _K = jax.vmap(
        lambda x_l, t_l, h_l: rk_stages(f, tab, x_l, t_l, h_l, params,
                                        combiner))(x_n, t_n, h_n)
    # the stacked adjoint-slope buffer keeps its stage axis LEADING, so the
    # lane axis of every leaf sits at axis 1 (vmap in_axes=1 below).
    L = alloc_stages(s, lam_next)
    lambda_stage_lanes = [
        jax.vmap(lambda lam_l, L_l, h_l, i=i: combiner.lambda_stage(
            lam_l, L_l, h_l, i), in_axes=(0, 1, 0)) for i in range(s)]
    gtheta = None
    dep = lam_next
    for i in reversed(range(s)):
        Lam_i = lambda_stage_lanes[i](lam_next, L, h_n)
        Xi = _barrier_with(Xs[i], dep)  # Xs: list of s lane-batched pytrees

        def stage_vjp(X_l, t_l, Lam_l):
            _, vjp_fn = jax.vjp(lambda X, th: f(X, t_l, th), X_l, params)
            return vjp_fn(Lam_l)

        xbar, thbar = jax.vmap(stage_vjp)(Xi, t_n + c[i] * h_n, Lam_i)
        l_i = jax.tree_util.tree_map(jnp.negative, xbar)
        L = set_stage(L, i, l_i)
        if b[i] == 0.0:  # Eq. (8): btilde_i = h_n, per lane
            contrib = jax.tree_util.tree_map(
                lambda g: lane_bcast(h_n, g).astype(g.dtype) * g, thbar)
        else:
            contrib = jax.tree_util.tree_map(
                lambda g: jnp.asarray(b[i], dtype=g.dtype) * g, thbar)
        gtheta = contrib if gtheta is None else _tree_add(gtheta, contrib)
        dep = l_i
    lam_n = jax.vmap(combiner.lambda_update,
                     in_axes=(0, 1, 0))(lam_next, L, h_n)
    gtheta = jax.tree_util.tree_map(
        lambda g: lane_bcast(h_n, g).astype(g.dtype) * g, gtheta)
    return lam_n, gtheta


def _masked_lanes_alg2_scan(f, tab, combiner, params, max_steps,
                            xs, ts, hs, n_acc, lam, gtheta):
    """Reverse Algorithm-2 scan over (max_steps, B) checkpoint rows.

    ``n_acc`` is (B,); rows >= a lane's count leave that lane's lambda and
    its grad-theta contribution untouched.  Rows beyond EVERY lane's count
    skip the stage recomputation entirely (lax.cond on any(valid)).
    """
    def body(carry, inputs):
        lam, gtheta = carry
        x_n, t_n, h_n, idx = inputs
        valid = idx < n_acc

        def live(args):
            lam, gtheta = args
            lam2, gstep = symplectic_step_adjoint_lanes(
                f, tab, x_n, t_n, h_n, params, lam, combiner)
            lam = jax.tree_util.tree_map(
                lambda a, b: jnp.where(lane_bcast(valid, a), b, a),
                lam, lam2)
            gsum = jax.tree_util.tree_map(
                lambda g: jnp.sum(jnp.where(lane_bcast(valid, g), g,
                                            jnp.zeros((), g.dtype)),
                                  axis=0), gstep)
            return lam, _tree_add(gtheta, gsum)

        def dead(args):
            return args

        out = jax.lax.cond(jnp.any(valid), live, dead, (lam, gtheta))
        return out, None

    idxs = jnp.arange(max_steps)
    (lam, gtheta), _ = jax.lax.scan(body, (lam, gtheta),
                                    (xs, ts, hs, idxs), reverse=True)
    return lam, gtheta


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _odeint_symplectic_adaptive_batched_r1(f: VectorField,
                                           tab: ButcherTableau,
                                           cfg: AdaptiveConfig,
                                           combine_backend: str,
                                           x0, t0r, t1r, params):
    sol = rk_solve_adaptive_batched(f, tab, x0, _unlift(t0r), _unlift(t1r),
                                    params, cfg,
                                    combine_backend)
    return apply_on_failure_lanes(sol.x_final, sol.succeeded, cfg.on_failure)


def odeint_symplectic_adaptive_batched(f: VectorField, tab: ButcherTableau,
                                       cfg: AdaptiveConfig,
                                       combine_backend: str,
                                       x0, t0, t1, params):
    """Batch-native adaptive solve (lane axis 0) with the exact symplectic
    adjoint replaying each lane's own accepted grid."""
    return _odeint_symplectic_adaptive_batched_r1(
        f, tab, cfg, combine_backend, x0, _lift(t0), _lift(t1), params)


def _symab_fwd(f, tab, cfg, combine_backend, x0, t0r, t1r, params):
    sol = rk_solve_adaptive_batched(f, tab, x0, _unlift(t0r), _unlift(t1r),
                                    params, cfg,
                                    combine_backend)
    res = (sol.xs, sol.ts, sol.hs, sol.n_accepted, params, t0r, t1r)
    x_final = apply_on_failure_lanes(sol.x_final, sol.succeeded,
                                     cfg.on_failure)
    return x_final, res


def _symab_bwd(f, tab, cfg, combine_backend, res, lam_N):
    xs, ts, hs, n_acc, params, t0, t1 = res
    combiner = get_combiner(tab, combine_backend)
    lam0, gtheta = _masked_lanes_alg2_scan(
        f, tab, combiner, params, cfg.max_steps, xs, ts, hs, n_acc,
        lam_N, _tree_zeros(params))
    return (lam0, _time_zero(t0), _time_zero(t1), gtheta)


_odeint_symplectic_adaptive_batched_r1.defvjp(_symab_fwd, _symab_bwd)


def _symab_saveat_solve(f, tab, cfg, combine_backend, x0, t0r, ts, params):
    obs, sols = rk_solve_adaptive_batched_saveat_stacked(
        f, tab, x0, _unlift(t0r), ts, params, cfg, combine_backend)
    res = (sols.xs, sols.ts, sols.hs, sols.n_accepted, params, t0r, ts)
    return obs, res


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _odeint_symplectic_saveat_adaptive_batched_r1(
        f: VectorField, tab: ButcherTableau, cfg: AdaptiveConfig,
        combine_backend: str, x0, t0r, ts, params):
    obs, _ = _symab_saveat_solve(f, tab, cfg, combine_backend,
                                 x0, t0r, ts, params)
    return obs


def odeint_symplectic_saveat_adaptive_batched(
        f: VectorField, tab: ButcherTableau, cfg: AdaptiveConfig,
        combine_backend: str, x0, t0, ts, params):
    """Batch-native adaptive solve observed at the (shared) times ``ts``.

    Per-lane controller state threads across observation boundaries
    (rk_solve_adaptive_batched_saveat_stacked); the backward pass walks the
    segments in reverse, injects the per-lane observation cotangent at each
    boundary, and replays every lane's own accepted grid inside the
    segment.  Exact per lane to rounding.
    """
    return _odeint_symplectic_saveat_adaptive_batched_r1(
        f, tab, cfg, combine_backend, x0, _lift(t0), ts, params)


def _symab_saveat_fwd(f, tab, cfg, combine_backend, x0, t0r, ts, params):
    return _symab_saveat_solve(f, tab, cfg, combine_backend,
                               x0, t0r, ts, params)


def _symab_saveat_bwd(f, tab, cfg, combine_backend, res, obs_bar):
    xs_all, ts_all, hs_all, n_accs, params, t0, ts = res
    combiner = get_combiner(tab, combine_backend)
    lam0 = jax.tree_util.tree_map(lambda l: jnp.zeros_like(l[0]), obs_bar)

    def seg_body(carry, seg):
        lam, gtheta = carry
        ob_i, seg_xs, seg_ts, seg_hs, n_acc = seg
        lam = _tree_add(lam, ob_i)
        lam, gtheta = _masked_lanes_alg2_scan(
            f, tab, combiner, params, cfg.max_steps,
            seg_xs, seg_ts, seg_hs, n_acc, lam, gtheta)
        return (lam, gtheta), None

    (lam, gtheta), _ = jax.lax.scan(
        seg_body, (lam0, _tree_zeros(params)),
        (obs_bar, xs_all, ts_all, hs_all, n_accs), reverse=True)
    return (lam, _time_zero(t0), _time_zero(ts), gtheta)


_odeint_symplectic_saveat_adaptive_batched_r1.defvjp(_symab_saveat_fwd,
                                                     _symab_saveat_bwd)
