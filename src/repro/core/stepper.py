"""Explicit solver state machine: one step of any driver as a pure function.

Every driver family in this repo — fixed grid, adaptive, batched adaptive,
and the SaveAt segment chains built on them — advances the same small bundle
of values: the integration clock, the state pytree, the controller's step
carry, counters, and the bounded checkpoint buffers Algorithm 1 of the paper
retains.  Before this module that bundle existed only as anonymous
``lax.while_loop`` / ``lax.scan`` carries duplicated across the drivers in
core/rk.py.  Here it is a first-class registered pytree, ``SolverState``,
plus a stepper API:

    stepper = AdaptiveStepper(f, tab, cfg, combine_backend)
    state   = stepper.init_state(x0, t0, t1)        # or lanes=B for a batch
    state   = stepper.advance(state, params)        # ONE attempted step:
                                                    #   trial, accept/reject,
                                                    #   commit
    stepper.is_done(state)                          # all lanes landed/budget
    sol     = stepper.finalize(state)               # Adaptive/Batched
                                                    #   AdaptiveSolution

``advance`` is a pure ``SolverState -> SolverState`` map, so a solve can be
paused anywhere, the state flattened / saved / restored / shipped across
hosts, and resumed bit-identically (tests/test_stepper.py): the paper's
memory bound is exactly the statement that this state is SMALL — one step's
worth, O(M + s + L) live — which is what makes it checkpointable at all.
The drivers in core/rk.py are thin loops over ``advance`` (``run``), and
the continuous-batching serve engine (repro.serve) drives the SAME
``advance`` one slice at a time, inserting new trajectories into free lanes
of a running state between calls.

Single-trajectory and lane-batched solves share one ``advance``: the state's
time-like fields are scalars for a single trajectory and (B,) for a batch
(``state.t.ndim`` selects the per-lane error norm and the lane-vmapped
step), and every controller rule — the unclamped-h landing carry, the
dtype-aware termination threshold, the PI factor, per-lane accept/commit —
is the identical arithmetic in both ranks, so lane b of a batched solve
bit-matches its single solve (tests/test_batch.py still pins this).

``SolverState.rtol``/``atol`` are optional per-solve (or per-lane) tolerance
OVERRIDES: ``None`` (the drivers) means the config's Python-float tolerances
are closed into the trace exactly as before, while the serve engine stores
(B,) arrays so heterogeneous tolerances ride one compiled ``advance``
without recompilation.

This module also owns the step-level primitives the steppers are built from
(``rk_step``, ``rk_stages``, the error norms, ``AdaptiveConfig`` and the
solution tuples); core/rk.py re-exports them, so existing import sites are
unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .combine import (StageCombiner, alloc_stages, append_stage,
                      get_combiner, set_stage)
from .tableau import ButcherTableau

Pytree = Any
VectorField = Callable[[Pytree, jnp.ndarray, Pytree], Pytree]
# f(x, t, params) -> dx/dt, pytree-in pytree-out.


# ---------------------------------------------------------------------------
# One explicit RK step (stage states, slopes, embedded error).
# ---------------------------------------------------------------------------

def rk_stages(f: VectorField, tab: ButcherTableau, x, t, h, params,
              combiner: Optional[StageCombiner] = None):
    """Compute all stage states X_i and slopes k_i for one step.

    Returns (Xs, K): ``Xs`` is a list of s stage-state pytrees, ``K`` the
    stacked slope buffer (leading stage dim s per leaf).  Purely forward;
    the symplectic backward pass re-runs this from a checkpoint (Alg. 2
    lines 3-7).
    """
    combiner = combiner or get_combiner(tab)
    s = tab.s
    K = alloc_stages(s, x)
    Xs = []
    for i in range(s):
        Xi = combiner.stage_state(x, K, h, i)
        ki = f(Xi, t + tab.c[i] * h, params)
        K = set_stage(K, i, ki)
        Xs.append(Xi)
    return Xs, K


def rk_step(f: VectorField, tab: ButcherTableau, x, t, h, params,
            combiner: Optional[StageCombiner] = None,
            with_error: Optional[bool] = None):
    """One explicit RK step: returns (x_next, err_estimate_or_None).

    ``with_error=False`` skips the embedded error estimate (the fixed-grid
    drivers pass it; there is no controller to consume the estimate).  The
    default (None) computes it whenever the tableau has error weights.
    """
    combiner = combiner or get_combiner(tab)
    if with_error is None:
        with_error = tab.b_err is not None
    Xs, K = rk_stages(f, tab, x, t, h, params, combiner)
    if not (with_error and tab.b_err is not None):
        return combiner.solution(x, K, h), None
    if tab.err_uses_fsal:
        # the error weights reference k_{s+1} = f(x_{n+1}); the solution must
        # come first, then one extra evaluation extends the slope buffer.
        x_next = combiner.solution(x, K, h)
        K_err = append_stage(K, f(x_next, t + h, params))
        return x_next, combiner.error(x, K_err, h)
    # both rows (b, b_err) combine the same s slopes: fuse into ONE pass.
    return combiner.solution_and_error(x, K, h)


# ---------------------------------------------------------------------------
# Adaptive controller pieces.
# ---------------------------------------------------------------------------

ON_FAILURE_POLICIES = ("nan", "ignore", "raise")


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    rtol: float = 1e-6
    atol: float = 1e-8
    max_steps: int = 256          # checkpoint buffer bound (accepted steps)
    max_attempts: int = 4096      # total trial-step bound
    safety: float = 0.9
    min_factor: float = 0.2
    max_factor: float = 10.0
    initial_step: float = 0.01
    # what odeint does with x_final when the while-loop exits via the
    # max_steps / max_attempts budget without reaching t1:
    #   "nan"    — poison every inexact leaf with NaN  [default]
    #   "ignore" — return the truncated state as-is (pre-fix behaviour)
    #   "raise"  — jax.debug.callback that raises at dispatch time
    on_failure: str = "nan"

    def __post_init__(self):
        if self.on_failure not in ON_FAILURE_POLICIES:
            raise ValueError(f"on_failure {self.on_failure!r} not in "
                             f"{ON_FAILURE_POLICIES}")


def _tol_like(v, leaf):
    """Cast an ARRAY tolerance to the leaf dtype so tolerances-as-data
    (the serve engine's per-lane rtol/atol) reproduce the Python-float
    path bit-for-bit: a weak float never promotes the scale computation,
    so a strong f64 tolerance array must not either (e.g. an f32 state
    under x64).  Python floats pass through untouched — the drivers'
    closed-into-the-trace path is byte-identical to before."""
    return v.astype(leaf.dtype) if isinstance(v, jax.Array) else v


def _error_norm(err, x, x_next, rtol, atol):
    leaves = zip(jax.tree_util.tree_leaves(err),
                 jax.tree_util.tree_leaves(x),
                 jax.tree_util.tree_leaves(x_next))
    total, count = 0.0, 0
    for e, a, b in leaves:
        scale = _tol_like(atol, a) \
            + _tol_like(rtol, a) * jnp.maximum(jnp.abs(a), jnp.abs(b))
        # accumulate in >= f32 but NEVER below the state dtype: an f32 norm
        # under x64 quantizes the accept/reject decisions of an f64 solve
        # (caught by the repro.analysis dtype rule).
        r = (e / scale).astype(jnp.promote_types(e.dtype, jnp.float32))
        total = total + jnp.sum(r * r)
        count += r.size
    return jnp.sqrt(total / count)


def _error_norm_lanes(err, x, x_next, rtol, atol):
    """Per-lane error norms for lane-batched states (lane axis 0 per leaf).

    This is ``jax.vmap`` of ``_error_norm`` itself, NOT a reimplementation:
    each lane's norm applies the identical per-leaf elementwise scale
    ``atol + rtol * max(|x|, |x_next|)`` and the identical element-count
    weighting across mixed-magnitude leaves as a single-trajectory solve of
    that lane — so masked per-lane step control accepts exactly the steps a
    loop of single solves would (tests/test_batch.py pins this for
    mixed-magnitude pytree states).  Returns shape (B,).

    ``rtol``/``atol`` are Python floats (shared across lanes — closed into
    the trace, the drivers' path) or (B,) arrays (per-lane tolerances, the
    serve engine's path — vmapped alongside the lanes).
    """
    if isinstance(rtol, jnp.ndarray) or isinstance(atol, jnp.ndarray):
        return jax.vmap(
            lambda e, a, b, rt, at: _error_norm(e, a, b, rt, at))(
                err, x, x_next, jnp.asarray(rtol), jnp.asarray(atol))
    return jax.vmap(
        lambda e, a, b: _error_norm(e, a, b, rtol, atol))(err, x, x_next)


def _time_resolution(t0, t1, dtype):
    """Smallest meaningful |t1 - t| for the termination test.

    The old fixed threshold (1e-14) is below float32 resolution for typical
    t, so with x64 disabled the loop could burn attempts re-trying steps
    whose ``t + h`` rounds back to ``t``.  Scale by the representable
    resolution of the interval instead: a few ulps of max(|t0|, |t1|,
    |t1 - t0|) in the working dtype.
    """
    eps = jnp.asarray(jnp.finfo(dtype).eps, dtype)
    scale = jnp.maximum(jnp.abs(t1 - t0),
                        jnp.maximum(jnp.abs(t0), jnp.abs(t1)))
    return 4.0 * eps * jnp.maximum(scale, eps)


def lane_bcast(v, leaf):
    """Broadcast a per-lane vector (B,) against a lane-batched leaf (B, ...).

    Also the degenerate scalar case: a () ``v`` reshapes to all-singleton
    dims, so one code path serves batched and unbatched policies."""
    return jnp.reshape(v, jnp.shape(v) + (1,) * (jnp.ndim(leaf) - 1))


def lane_count(x0: Pytree) -> int:
    """Lane count B of a lane-batched state: every leaf must carry the same
    leading lane axis (``solve(..., batch_axis=0)``)."""
    leaves = jax.tree_util.tree_leaves(x0)
    if not leaves:
        raise ValueError("batched solve needs a non-empty state pytree")
    sizes = set()
    for l in leaves:
        if jnp.ndim(l) < 1:
            raise ValueError(
                "batch_axis=0 requires every state leaf to carry a leading "
                f"lane axis; got a rank-0 leaf {l!r}")
        sizes.add(jnp.shape(l)[0])
    if len(sizes) != 1:
        raise ValueError(
            "batch_axis=0 requires every state leaf to share the same "
            f"leading lane-axis size; got sizes {sorted(sizes)}")
    return sizes.pop()


# ---------------------------------------------------------------------------
# Solution tuples (what finalize() returns; the custom-VJP residual contract).
# ---------------------------------------------------------------------------

class FixedSolution(NamedTuple):
    x_final: Pytree
    xs: Pytree          # stacked checkpoints x_0..x_{N-1} (leading dim N)
    ts: jnp.ndarray     # t_0..t_{N-1}
    h: jnp.ndarray      # scalar step size


class AdaptiveSolution(NamedTuple):
    x_final: Pytree
    xs: Pytree           # (max_steps, ...) accepted checkpoints, zero-padded
    ts: jnp.ndarray      # (max_steps,)
    hs: jnp.ndarray      # (max_steps,)
    n_accepted: jnp.ndarray  # int32 scalar
    n_fevals: jnp.ndarray    # int32 scalar
    succeeded: jnp.ndarray   # bool scalar: reached t1 within the budgets
    h_final: jnp.ndarray     # UNclamped controller step at exit (see rk.py)
    n_attempts: jnp.ndarray  # int32 scalar: total trial steps (acc + rej)


class BatchedAdaptiveSolution(NamedTuple):
    """Per-lane results of a batch-native adaptive solve (lane count B).

    The checkpoint buffers keep the step axis LEADING — ``xs`` leaves are
    (max_steps, B, ...), ``ts``/``hs`` are (max_steps, B) — so the
    symplectic backward pass scans step rows exactly like the unbatched
    driver, masking each lane by its own ``n_accepted``.
    """
    x_final: Pytree          # per-lane final states (lane axis 0)
    xs: Pytree               # (max_steps, B, ...) accepted checkpoints
    ts: jnp.ndarray          # (max_steps, B)
    hs: jnp.ndarray          # (max_steps, B)
    n_accepted: jnp.ndarray  # (B,) int32
    n_fevals: jnp.ndarray    # (B,) int32: per-lane f evaluations
    succeeded: jnp.ndarray   # (B,) bool: lane reached t1 within budgets
    h_final: jnp.ndarray     # (B,) unclamped controller step at lane exit
    n_attempts: jnp.ndarray  # (B,) int32: per-lane trial steps (acc + rej)


# ---------------------------------------------------------------------------
# SolverState: the full between-steps state of an adaptive solve.
# ---------------------------------------------------------------------------

class SolverState(NamedTuple):
    """Everything an adaptive solve carries between attempted steps.

    A registered pytree (NamedTuple of arrays): flatten/unflatten, jit
    boundaries, device_put/get, and donation all work on it directly.  All
    time-like / counter fields are scalars () for a single trajectory or
    (B,) for a lane-batched solve; the checkpoint buffers keep the step
    axis leading ((max_steps, ...) / (max_steps, B, ...)).

    t0, t1      — the integration interval (per lane when batched; t1 is
                  DATA, so the serve engine can insert a new trajectory
                  with its own horizon into a free lane of a running state).
    t, x, h     — the clock, the state pytree, and the controller's
                  UNCLAMPED step carry.
    n_accepted, n_attempts, n_fevals — int32 controller counters.
    xs, ts, hs  — the bounded accepted-checkpoint buffers of Algorithm 1
                  (rows >= n_accepted are scratch).
    rtol, atol  — optional tolerance overrides: ``None`` means the
                  AdaptiveConfig's Python-float tolerances are closed into
                  the trace (the drivers — zero numerics change); arrays
                  mean per-solve / per-lane tolerances as runtime data (the
                  serve engine's heterogeneous requests).
    """
    t0: jnp.ndarray
    t1: jnp.ndarray
    t: jnp.ndarray
    x: Pytree
    h: jnp.ndarray
    n_accepted: jnp.ndarray
    n_attempts: jnp.ndarray
    n_fevals: jnp.ndarray
    xs: Pytree
    ts: jnp.ndarray
    hs: jnp.ndarray
    rtol: Optional[jnp.ndarray] = None
    atol: Optional[jnp.ndarray] = None

    @property
    def batched(self) -> bool:
        return jnp.ndim(self.t) == 1

    @property
    def lanes(self) -> Optional[int]:
        return jnp.shape(self.t)[0] if self.batched else None


def _commit_row(col, val, idx, do):
    """Masked write of ``val`` into row ``idx`` of a checkpoint buffer.

    Touches only row idx (read-select-write), so a trial step costs
    O(state), not an O(max_steps * state) whole-buffer select.  ``col`` is
    a whole (max_steps, ...) buffer for a single trajectory, or ONE lane's
    column under the vmap in ``_commit_lanes``.  ``idx`` may equal
    max_steps for an exhausted lane: the dynamic read/write clamps to the
    last row and ``do`` is necessarily False there, so the write is the
    identity.
    """
    cur = jax.lax.dynamic_index_in_dim(col, idx, 0, keepdims=False)
    new = jnp.where(do, val.astype(col.dtype), cur)
    return jax.lax.dynamic_update_index_in_dim(col, new, idx, 0)


_commit_lanes = jax.vmap(_commit_row, in_axes=(1, 0, 0, 0), out_axes=1)


@dataclasses.dataclass(frozen=True)
class AdaptiveStepper:
    """The PI-controlled adaptive solver as an explicit state machine.

    Static configuration (the vector field, the tableau, the controller
    config, the combine backend) lives here; everything dynamic lives in
    the ``SolverState`` each method consumes and returns.  ``params`` stays
    an explicit argument of ``advance``/``run`` — never closed over — so
    jitted engine steps take it as a real (cacheable, non-baked) input.

    The controller rules are the ones documented on ``rk_solve_adaptive``
    (core/rk.py): per-trial clamp with an unclamped carry, dtype-aware
    termination, non-finite-h bailout, per-lane masking when batched.
    """
    f: VectorField
    tab: ButcherTableau
    cfg: AdaptiveConfig
    combine_backend: str = "auto"

    def __post_init__(self):
        if self.tab.b_err is None:
            raise ValueError(
                f"tableau {self.tab.name} has no embedded error estimate")

    @property
    def combiner(self) -> StageCombiner:
        return get_combiner(self.tab, self.combine_backend)

    # -- lifecycle ----------------------------------------------------------
    def init_state(self, x0, t0, t1, h0=None, *,
                   lanes: Optional[int] = None,
                   rtol=None, atol=None) -> SolverState:
        """Fresh state at t0.  ``lanes=B`` builds a lane-batched state (x0
        leaves carry lane axis 0); ``h0`` seeds the controller with a step
        MAGNITUDE (e.g. the previous SaveAt segment's h_final), falling
        back to ``cfg.initial_step`` when absent or zero; ``rtol``/``atol``
        (scalars or (B,)) opt into tolerances-as-data."""
        cfg = self.cfg
        dtype = jnp.result_type(float)
        shape = () if lanes is None else (lanes,)
        t0 = jnp.broadcast_to(jnp.asarray(t0, dtype=dtype), shape)
        t1 = jnp.broadcast_to(jnp.asarray(t1, dtype=dtype), shape)
        direction = jnp.sign(t1 - t0)
        h0_abs = jnp.abs(jnp.broadcast_to(
            jnp.asarray(cfg.initial_step if h0 is None else h0, dtype),
            shape))
        h = direction * jnp.where(h0_abs > 0, h0_abs,
                                  jnp.asarray(cfg.initial_step, dtype))
        xs = jax.tree_util.tree_map(
            lambda l: jnp.zeros((cfg.max_steps,) + jnp.shape(l), l.dtype),
            x0)
        counter = jnp.zeros(shape, jnp.int32)
        return SolverState(
            t0=t0, t1=t1, t=t0, x=x0, h=h,
            n_accepted=counter, n_attempts=counter, n_fevals=counter,
            xs=xs,
            ts=jnp.zeros((cfg.max_steps,) + shape, dtype),
            hs=jnp.zeros((cfg.max_steps,) + shape, dtype),
            rtol=(None if rtol is None
                  else jnp.broadcast_to(jnp.asarray(rtol, dtype), shape)),
            atol=(None if atol is None
                  else jnp.broadcast_to(jnp.asarray(atol, dtype), shape)))

    def lanes_active(self, state: SolverState):
        """Per-lane liveness: () bool or (B,) bool.  A lane is active until
        it lands within the dtype-aware resolution of t1, exhausts a
        budget, or its h carry goes non-finite (a NaN-poisoned lane costs
        one doomed trial, then drops out instead of spinning
        max_attempts)."""
        cfg = self.cfg
        direction = jnp.sign(state.t1 - state.t0)
        t_res = _time_resolution(state.t0, state.t1, state.t.dtype)
        return (direction * (state.t1 - state.t) > t_res) \
            & (state.n_accepted < cfg.max_steps) \
            & (state.n_attempts < cfg.max_attempts) \
            & jnp.isfinite(state.h)

    def is_done(self, state: SolverState):
        return jnp.logical_not(jnp.any(self.lanes_active(state)))

    def advance(self, state: SolverState, params) -> SolverState:
        """ONE attempted step: trial at the clamped step, per-lane (or
        scalar) error norm, accept/reject, commit of accepted checkpoints,
        controller update.  Pure; inactive lanes (done, exhausted, or free
        engine slots) pass through untouched, so driving ``advance`` past
        completion is the identity on the state."""
        cfg, tab = self.cfg, self.tab
        batched = state.batched
        err_exp = -1.0 / (tab.err_order + 1.0)
        direction = jnp.sign(state.t1 - state.t0)
        t, x, h = state.t, state.x, state.h
        active = self.lanes_active(state)
        # clamp the TRIAL step so we land exactly on t1; the carried h
        # stays unclamped (see rk_solve_adaptive's docstring).
        clamped = jnp.abs(h) > jnp.abs(state.t1 - t)
        h_eff = direction * jnp.minimum(jnp.abs(h), jnp.abs(state.t1 - t))
        if batched:
            x_next, err = jax.vmap(
                lambda x_l, t_l, h_l: rk_step(
                    self.f, tab, x_l, t_l, h_l, params, self.combiner,
                    with_error=True))(x, t, h_eff)
        else:
            x_next, err = rk_step(self.f, tab, x, t, h_eff, params,
                                  self.combiner, with_error=True)
        rtol = cfg.rtol if state.rtol is None else state.rtol
        atol = cfg.atol if state.atol is None else state.atol
        if batched:
            enorm = _error_norm_lanes(err, x, x_next, rtol, atol)
        else:
            enorm = _error_norm(err, x, x_next, rtol, atol)
        accept = enorm <= 1.0
        factor = jnp.clip(cfg.safety * jnp.power(jnp.maximum(enorm, 1e-10),
                                                 err_exp),
                          cfg.min_factor, cfg.max_factor)
        # clamped landing steps never contaminate the carried step: an
        # ACCEPTED one keeps the natural h, a REJECTED one shrinks from the
        # unclamped h (not from h_eff — the t1 gap is not the controller's
        # step; shrinking from it collapses the carry for continuations).
        h_new = jnp.where(accept & clamped, h, h * factor)
        h = jnp.where(active, h_new, h)    # inactive lanes freeze the carry
        do = active & accept
        n_acc = state.n_accepted
        commit = _commit_lanes if batched else _commit_row
        xs = jax.tree_util.tree_map(
            lambda buf, val: commit(buf, val, n_acc, do), state.xs, x)
        ts = commit(state.ts, t, n_acc, do)
        hs = commit(state.hs, h_eff, n_acc, do)
        t = jnp.where(do, t + h_eff, t)
        x = jax.tree_util.tree_map(
            lambda a, b: jnp.where(lane_bcast(do, a), b, a), x, x_next)
        fevals = tab.s + (1 if tab.err_uses_fsal else 0)
        return state._replace(
            t=t, x=x, h=h,
            n_accepted=n_acc + do.astype(jnp.int32),
            n_attempts=state.n_attempts + active.astype(jnp.int32),
            n_fevals=state.n_fevals + active.astype(jnp.int32) * fevals,
            xs=xs, ts=ts, hs=hs)

    def run(self, state: SolverState, params) -> SolverState:
        """Drive ``advance`` until ``is_done``: ONE lax.while_loop whose
        carry IS the SolverState.  This is the whole driver."""
        return jax.lax.while_loop(
            lambda s: jnp.any(self.lanes_active(s)),
            lambda s: self.advance(s, params), state)

    def succeeded(self, state: SolverState):
        direction = jnp.sign(state.t1 - state.t0)
        t_res = _time_resolution(state.t0, state.t1, state.t.dtype)
        return jnp.logical_not(direction * (state.t1 - state.t) > t_res)

    def finalize(self, state: SolverState):
        """Freeze a state into the driver-facing solution tuple
        (AdaptiveSolution, or BatchedAdaptiveSolution for a lane-batched
        state).  The state stays valid — finalize is a view, not a
        consume."""
        cls = BatchedAdaptiveSolution if state.batched else AdaptiveSolution
        return cls(state.x, state.xs, state.ts, state.hs, state.n_accepted,
                   state.n_fevals, self.succeeded(state), state.h,
                   state.n_attempts)


# ---------------------------------------------------------------------------
# Fixed-grid stepper.
# ---------------------------------------------------------------------------

class FixedSolverState(NamedTuple):
    """Between-steps state of an N-equal-steps fixed-grid solve: the clock
    is ``(t0, h, n)`` (t_n = t0 + n*h is derived, never accumulated), the
    checkpoints land in preallocated (n_steps, ...) buffers.  A registered
    pytree, pausable/resumable exactly like ``SolverState``."""
    t0: jnp.ndarray
    h: jnp.ndarray
    n: jnp.ndarray        # int32: steps taken so far
    x: Pytree
    xs: Pytree            # (n_steps, ...) checkpoints x_0..x_{N-1}
    ts: jnp.ndarray       # (n_steps,)


@dataclasses.dataclass(frozen=True)
class FixedStepper:
    """N equal steps as a state machine.  ``run`` is a lax.scan over
    ``advance`` (scan, not while_loop: the fixed driver must stay
    reverse-differentiable for DirectBackprop / RematStep / RematSolve)."""
    f: VectorField
    tab: ButcherTableau
    n_steps: int
    combine_backend: str = "auto"

    @property
    def combiner(self) -> StageCombiner:
        return get_combiner(self.tab, self.combine_backend)

    def init_state(self, x0, t0, t1) -> FixedSolverState:
        t0 = jnp.asarray(t0, dtype=jnp.result_type(float))
        t1 = jnp.asarray(t1, dtype=t0.dtype)
        h = (t1 - t0) / self.n_steps
        xs = jax.tree_util.tree_map(
            lambda l: jnp.zeros((self.n_steps,) + jnp.shape(l), l.dtype),
            x0)
        return FixedSolverState(
            t0=t0, h=h, n=jnp.int32(0), x=x0, xs=xs,
            ts=jnp.zeros((self.n_steps,), t0.dtype))

    def is_done(self, state: FixedSolverState):
        return state.n >= self.n_steps

    def advance(self, state: FixedSolverState, params) -> FixedSolverState:
        """One fixed step: checkpoint the pre-step state, step without the
        embedded error estimate (no controller to consume it)."""
        t = state.t0 + state.n.astype(state.t0.dtype) * state.h
        x_next, _ = rk_step(self.f, self.tab, state.x, t, state.h, params,
                            self.combiner, with_error=False)
        xs = jax.tree_util.tree_map(
            lambda buf, val: jax.lax.dynamic_update_index_in_dim(
                buf, val.astype(buf.dtype), state.n, 0), state.xs, state.x)
        ts = jax.lax.dynamic_update_index_in_dim(state.ts, t, state.n, 0)
        return state._replace(x=x_next, n=state.n + 1, xs=xs, ts=ts)

    def run(self, state: FixedSolverState, params) -> FixedSolverState:
        def body(s, _):
            return self.advance(s, params), None

        state, _ = jax.lax.scan(body, state, None, length=self.n_steps)
        return state

    def finalize(self, state: FixedSolverState) -> FixedSolution:
        return FixedSolution(state.x, state.xs, state.ts, state.h)
