"""Continuous adjoint method (Chen et al. 2018 baseline).

Backward pass integrates the augmented system

    d/dt [x, lambda, lambda_theta] =
        [f(x,t,theta), -(df/dx)^T lambda, -(df/dtheta)^T lambda]

backward in time from (x_N, dL/dx_N, 0).  In discrete time this is NOT the
exact gradient of the discrete forward map (Remark 1 fails after
discretization) — the error is O(h^p) and the tests quantify it against the
symplectic adjoint.  Mirrors torchdiffeq's ``odeint_adjoint``: memory O(1) in
the step count, cost >= 2x forward (and in practice the backward tolerance
forces N_tilde > N; ``backward_steps_multiplier`` models that knob for the
fixed-grid variant).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .rk import (AdaptiveConfig, VectorField, apply_on_failure,
                 apply_on_failure_lanes, lane_count, rk_solve_adaptive,
                 rk_solve_adaptive_batched, rk_solve_fixed,
                 time_lift as _lift, time_unlift as _unlift,
                 time_zero_cotangent as _time_zero)
from .tableau import ButcherTableau

Pytree = Any


def _aug_dynamics(f: VectorField):
    def aug(state, t, params):
        x, lam, _ = state
        # reverse-time integration: we integrate s = -t forward, so negate.
        fx, vjp_fn = jax.vjp(lambda xx, th: f(xx, t, th), x, params)
        xbar, thbar = vjp_fn(lam)
        return (fx,
                jax.tree_util.tree_map(jnp.negative, xbar),
                jax.tree_util.tree_map(jnp.negative, thbar))
    return aug


# All custom_vjp drivers below take their scalar times as (1,)-shaped
# arrays (see rk.time_lift); the public odeint_* wrappers keep the scalar
# signature and lift at the boundary.

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _odeint_adjoint_r1(f: VectorField, tab: ButcherTableau, n_steps: int,
                       backward_steps_multiplier: int, combine_backend: str,
                       x0, t0r, t1r, params):
    sol = rk_solve_fixed(f, tab, x0, _unlift(t0r), _unlift(t1r), n_steps,
                         params,
                         combine_backend)
    return sol.x_final


def odeint_adjoint(f: VectorField, tab: ButcherTableau, n_steps: int,
                   backward_steps_multiplier: int, combine_backend: str,
                   x0, t0, t1, params):
    return _odeint_adjoint_r1(f, tab, n_steps, backward_steps_multiplier,
                              combine_backend, x0, _lift(t0), _lift(t1),
                              params)


def _adj_fwd(f, tab, n_steps, bmult, combine_backend, x0, t0r, t1r, params):
    sol = rk_solve_fixed(f, tab, x0, _unlift(t0r), _unlift(t1r), n_steps,
                         params,
                         combine_backend)
    # O(M): only the final state is retained (plus params; t0/t1 are the
    # PRIMAL time values so the bwd can emit dtype-matched cotangents).
    return sol.x_final, (sol.x_final, t0r, t1r, params)


def _adj_bwd(f, tab, n_steps, bmult, combine_backend, res, lam_N):
    xN, t0r, t1r, params = res
    aug = _aug_dynamics(f)
    gtheta0 = jax.tree_util.tree_map(jnp.zeros_like, params)
    state_N = (xN, lam_N, gtheta0)
    # integrate backward: t goes t1 -> t0 (negative step).
    sol = rk_solve_fixed(aug, tab, state_N, _unlift(t1r), _unlift(t0r),
                         n_steps * bmult, params, combine_backend)
    x0_rec, lam0, gtheta = sol.x_final
    # zero time cotangents in the dtypes the caller actually passed
    return (lam0, _time_zero(t0r), _time_zero(t1r), gtheta)


_odeint_adjoint_r1.defvjp(_adj_fwd, _adj_bwd)


# ---------------------------------------------------------------------------
# Adaptive variant: forward adaptive solve; backward adaptive solve of the
# augmented system with its own (typically tighter) tolerances.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _odeint_adjoint_adaptive_r1(f: VectorField, tab: ButcherTableau,
                                cfg: AdaptiveConfig, bwd_cfg: AdaptiveConfig,
                                combine_backend: str, x0, t0r, t1r, params):
    sol = rk_solve_adaptive(f, tab, x0, _unlift(t0r), _unlift(t1r), params,
                            cfg,
                            combine_backend)
    return apply_on_failure(sol.x_final, sol.succeeded, cfg.on_failure)


def odeint_adjoint_adaptive(f: VectorField, tab: ButcherTableau,
                            cfg: AdaptiveConfig, bwd_cfg: AdaptiveConfig,
                            combine_backend: str, x0, t0, t1, params):
    return _odeint_adjoint_adaptive_r1(f, tab, cfg, bwd_cfg,
                                       combine_backend, x0, _lift(t0),
                                       _lift(t1), params)


def _adja_fwd(f, tab, cfg, bwd_cfg, combine_backend, x0, t0r, t1r, params):
    sol = rk_solve_adaptive(f, tab, x0, _unlift(t0r), _unlift(t1r), params,
                            cfg,
                            combine_backend)
    x_final = apply_on_failure(sol.x_final, sol.succeeded, cfg.on_failure)
    return x_final, (x_final, t0r, t1r, params)


def _adja_bwd(f, tab, cfg, bwd_cfg, combine_backend, res, lam_N):
    xN, t0r, t1r, params = res
    aug = _aug_dynamics(f)
    gtheta0 = jax.tree_util.tree_map(jnp.zeros_like, params)
    sol = rk_solve_adaptive(aug, tab, (xN, lam_N, gtheta0), _unlift(t1r),
                            _unlift(t0r),
                            params, bwd_cfg, combine_backend)
    # a truncated backward solve is a silently wrong gradient: poison it
    # (or raise) per the backward config's policy too.
    _, lam0, gtheta = apply_on_failure(sol.x_final, sol.succeeded,
                                       bwd_cfg.on_failure)
    return (lam0, _time_zero(t0r), _time_zero(t1r), gtheta)


_odeint_adjoint_adaptive_r1.defvjp(_adja_fwd, _adja_bwd)


# ---------------------------------------------------------------------------
# Batch-native adaptive variant: the backward augmented solve ALSO runs under
# masked per-lane control, so each lane's adjoint is integrated on its own
# backward grid (a stiff lane cannot perturb its batchmates' backward
# tolerances).  The augmented state carries a per-lane grad-theta
# accumulator — leaves (B,) + param shape, i.e. O(B L) backward memory: the
# price of per-lane backward grids, documented in docs/batching.md.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _odeint_adjoint_adaptive_batched_r1(f: VectorField, tab: ButcherTableau,
                                        cfg: AdaptiveConfig,
                                        bwd_cfg: AdaptiveConfig,
                                        combine_backend: str,
                                        x0, t0r, t1r, params):
    sol = rk_solve_adaptive_batched(f, tab, x0, _unlift(t0r), _unlift(t1r),
                                    params, cfg,
                                    combine_backend)
    return apply_on_failure_lanes(sol.x_final, sol.succeeded, cfg.on_failure)


def odeint_adjoint_adaptive_batched(f: VectorField, tab: ButcherTableau,
                                    cfg: AdaptiveConfig,
                                    bwd_cfg: AdaptiveConfig,
                                    combine_backend: str,
                                    x0, t0, t1, params):
    return _odeint_adjoint_adaptive_batched_r1(f, tab, cfg, bwd_cfg,
                                               combine_backend, x0,
                                               _lift(t0), _lift(t1), params)


def _adjab_fwd(f, tab, cfg, bwd_cfg, combine_backend, x0, t0r, t1r, params):
    sol = rk_solve_adaptive_batched(f, tab, x0, _unlift(t0r), _unlift(t1r),
                                    params, cfg,
                                    combine_backend)
    x_final = apply_on_failure_lanes(sol.x_final, sol.succeeded,
                                     cfg.on_failure)
    return x_final, (x_final, t0r, t1r, params)


def _adjab_bwd(f, tab, cfg, bwd_cfg, combine_backend, res, lam_N):
    xN, t0r, t1r, params = res
    B = lane_count(xN)
    aug = _aug_dynamics(f)
    gtheta0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros((B,) + jnp.shape(p), jnp.asarray(p).dtype),
        params)
    sol = rk_solve_adaptive_batched(aug, tab, (xN, lam_N, gtheta0),
                                    _unlift(t1r), _unlift(t0r), params,
                                            bwd_cfg,
                                    combine_backend)
    # a lane whose backward solve was truncated is a silently wrong
    # gradient for THAT lane: poison per lane (the lane-summed grad-theta
    # inherits the poison — one bad lane taints the shared parameter
    # gradient, which is exactly what a sum of per-lane gradients means).
    _, lam0, gtheta_lanes = apply_on_failure_lanes(
        sol.x_final, sol.succeeded, bwd_cfg.on_failure)
    gtheta = jax.tree_util.tree_map(lambda g: jnp.sum(g, axis=0),
                                    gtheta_lanes)
    return (lam0, _time_zero(t0r), _time_zero(t1r), gtheta)


_odeint_adjoint_adaptive_batched_r1.defvjp(_adjab_fwd, _adjab_bwd)
