"""Core neural-ODE library: tableaus, RK solvers, and the symplectic adjoint.

Public API (composable, core/api.py):
    solve, Solution, SaveAt, GradientStrategy, SymplecticAdjoint,
    DirectBackprop, RematStep, RematSolve, ContinuousAdjoint,
    register_gradient, as_gradient, GRADIENT_REGISTRY, capability_matrix,
    AdaptiveConfig, get_tableau, ButcherTableau,
    COMBINE_BACKENDS, StageCombiner, get_combiner

Legacy front-ends (deprecated shims, core/odeint.py):
    odeint, odeint_with_stats, GRAD_MODES, TS_MODES
"""
from .combine import (COMBINE_BACKENDS, StageCombiner, alloc_stages,
                      get_combiner, set_stage, stage_prefix, stage_suffix)
from .api import (GRADIENT_REGISTRY, STEPPING_KINDS, SAVEAT_KINDS,
                  ContinuousAdjoint, DirectBackprop, GradientStrategy,
                  RematSolve, RematStep, SaveAt, Solution, SymplecticAdjoint,
                  as_gradient, batched_capability_matrix, capability_matrix,
                  mesh_capability_matrix, register_gradient, solve)
from .odeint import GRAD_MODES, TS_MODES, odeint, odeint_with_stats
from .rk import (ON_FAILURE_POLICIES, AdaptiveConfig, AdaptiveSolution,
                 BatchedAdaptiveSolution, apply_on_failure,
                 apply_on_failure_lanes, hermite_observe, lane_count,
                 rk_solve_adaptive, rk_solve_adaptive_batched,
                 rk_solve_adaptive_batched_saveat_stacked,
                 rk_solve_adaptive_saveat, rk_solve_adaptive_saveat_stacked,
                 rk_solve_fixed, rk_stages, rk_step, tree_scale_add)
from .stepper import (AdaptiveStepper, FixedSolverState, FixedStepper,
                      SolverState)
from .symplectic import (odeint_symplectic, odeint_symplectic_adaptive,
                         odeint_symplectic_adaptive_batched,
                         odeint_symplectic_saveat,
                         odeint_symplectic_saveat_adaptive,
                         odeint_symplectic_saveat_adaptive_batched,
                         symplectic_step_adjoint,
                         symplectic_step_adjoint_lanes)
from .adjoint import (odeint_adjoint, odeint_adjoint_adaptive,
                      odeint_adjoint_adaptive_batched)
from .backprop import odeint_backprop, odeint_remat_solve, odeint_remat_step
from .tableau import HERMITE_DENSE_W, TABLEAUS, ButcherTableau, get_tableau

__all__ = [
    "solve", "Solution", "SaveAt", "GradientStrategy", "SymplecticAdjoint",
    "DirectBackprop", "RematStep", "RematSolve", "ContinuousAdjoint",
    "register_gradient", "as_gradient", "GRADIENT_REGISTRY",
    "capability_matrix", "batched_capability_matrix",
    "mesh_capability_matrix",
    "STEPPING_KINDS", "SAVEAT_KINDS",
    "odeint", "odeint_with_stats", "GRAD_MODES", "TS_MODES",
    "AdaptiveConfig", "AdaptiveSolution", "BatchedAdaptiveSolution",
    "ON_FAILURE_POLICIES",
    "COMBINE_BACKENDS", "StageCombiner", "get_combiner", "alloc_stages",
    "set_stage", "stage_prefix", "stage_suffix",
    "rk_solve_fixed", "rk_solve_adaptive", "rk_solve_adaptive_batched",
    "rk_solve_adaptive_saveat", "rk_solve_adaptive_saveat_stacked",
    "rk_solve_adaptive_batched_saveat_stacked", "lane_count",
    "rk_step", "rk_stages", "tree_scale_add", "apply_on_failure",
    "apply_on_failure_lanes",
    "SolverState", "FixedSolverState", "AdaptiveStepper", "FixedStepper",
    "hermite_observe", "odeint_symplectic", "odeint_symplectic_adaptive",
    "odeint_symplectic_adaptive_batched",
    "odeint_symplectic_saveat", "odeint_symplectic_saveat_adaptive",
    "odeint_symplectic_saveat_adaptive_batched",
    "symplectic_step_adjoint", "symplectic_step_adjoint_lanes",
    "odeint_adjoint", "odeint_adjoint_adaptive",
    "odeint_adjoint_adaptive_batched",
    "odeint_backprop", "odeint_remat_step", "odeint_remat_solve",
    "TABLEAUS", "ButcherTableau", "get_tableau", "HERMITE_DENSE_W",
]
