"""Core neural-ODE library: tableaus, RK solvers, and the symplectic adjoint.

Public API:
    odeint, odeint_with_stats, AdaptiveConfig, get_tableau, ButcherTableau,
    GRAD_MODES, COMBINE_BACKENDS, StageCombiner, get_combiner
"""
from .combine import (COMBINE_BACKENDS, StageCombiner, alloc_stages,
                      get_combiner, set_stage, stage_prefix, stage_suffix)
from .odeint import GRAD_MODES, TS_MODES, odeint, odeint_with_stats
from .rk import (ON_FAILURE_POLICIES, AdaptiveConfig, AdaptiveSolution,
                 apply_on_failure, hermite_observe, rk_solve_adaptive,
                 rk_solve_adaptive_saveat, rk_solve_adaptive_saveat_stacked,
                 rk_solve_fixed, rk_stages, rk_step, tree_scale_add)
from .symplectic import (odeint_symplectic, odeint_symplectic_adaptive,
                         odeint_symplectic_saveat,
                         odeint_symplectic_saveat_adaptive,
                         symplectic_step_adjoint)
from .adjoint import odeint_adjoint, odeint_adjoint_adaptive
from .backprop import odeint_backprop, odeint_remat_solve, odeint_remat_step
from .tableau import HERMITE_DENSE_W, TABLEAUS, ButcherTableau, get_tableau

__all__ = [
    "odeint", "odeint_with_stats", "GRAD_MODES", "TS_MODES",
    "AdaptiveConfig", "AdaptiveSolution", "ON_FAILURE_POLICIES",
    "COMBINE_BACKENDS", "StageCombiner", "get_combiner", "alloc_stages",
    "set_stage", "stage_prefix", "stage_suffix",
    "rk_solve_fixed", "rk_solve_adaptive", "rk_solve_adaptive_saveat",
    "rk_solve_adaptive_saveat_stacked",
    "rk_step", "rk_stages", "tree_scale_add", "apply_on_failure",
    "hermite_observe", "odeint_symplectic", "odeint_symplectic_adaptive",
    "odeint_symplectic_saveat", "odeint_symplectic_saveat_adaptive",
    "symplectic_step_adjoint", "odeint_adjoint", "odeint_adjoint_adaptive",
    "odeint_backprop", "odeint_remat_step", "odeint_remat_solve",
    "TABLEAUS", "ButcherTableau", "get_tableau", "HERMITE_DENSE_W",
]
