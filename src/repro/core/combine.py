"""Stacked stage buffers + the StageCombiner: ALL RK stage linear algebra.

The stage representation across the solver stack is a *stacked slope buffer*:
for a state pytree ``x`` the slopes k_1..k_s live as one buffer per leaf with
a leading stage dimension — leaf shape ``(s,) + x_leaf.shape``.  Every linear
combination the solvers need is a *row combine* against that buffer,

    out = base + h * sum_i coefs[i] * K[i],

which is memory-bound (arithmetic intensity < 1 FLOP/byte), so the entire
question is how many HBM passes it costs.  The chained per-stage AXPY of the
old list-of-pytrees layout costs s+2 passes; a row combine over the stacked
buffer costs exactly one read of (base, K) and one write of out — see
docs/stage_combine.md for the arithmetic.

The StageCombiner routes four solver operations through that primitive:

  * forward stage states   X_i = x + h * sum_{j<i} a_ij k_j          (Eq. 5)
  * the step update        x_{n+1} = x + h * sum_i b_i k_i           (Eq. 5)
  * the embedded error     err = h * sum_i b_err_i k_i   (+ FSAL slope)
  * the backward recursion Lambda_i / lambda_n of Algorithm 2        (Eq. 7/8)

and dispatches each leaf either to the pure-jnp oracle (a stage-order
accumulation over the stacked buffer, unrolled so XLA fuses it into one
elementwise pass) or to the Pallas kernel
``kernels/butcher_combine.py`` (one VMEM-tiled pass on TPU), selected by the
``combine_backend`` knob on ``odeint``:

  auto    — Pallas on TPU backends, jnp elsewhere                    [default]
  jnp     — always the jnp oracle (dtype-preserving; exact in f64)
  pallas  — always the Pallas kernel (interpret mode off-TPU)

Both backends accumulate in ``promote_types(state_dtype, float32)``: >= f32
for low-precision states, f64 for f64 states — so x64 exact-gradient tests
hold on either backend.

For the backward recursion the h-dependence of the paper's Eq. (7)/(8)
coefficients (btilde_j = b_j, or h_n for the I0 = {i : b_i = 0} stages) is
factored into three h-independent numpy matrices R/P/Q precomputed per
tableau, so the per-stage coefficient row is just R[i] + h P[i] + h^2 Q[i].
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from .tableau import HERMITE_DENSE_W, ButcherTableau

Pytree = Any

COMBINE_BACKENDS = ("auto", "jnp", "pallas")

__all__ = ["COMBINE_BACKENDS", "StageCombiner", "get_combiner",
           "alloc_stages", "set_stage", "stage_prefix", "stage_suffix",
           "resolve_backend"]


def resolve_backend(backend: str) -> str:
    if backend not in COMBINE_BACKENDS:
        raise ValueError(
            f"combine_backend {backend!r} not in {COMBINE_BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return backend


# ---------------------------------------------------------------------------
# Stacked slope buffers
# ---------------------------------------------------------------------------

def alloc_stages(s: int, x: Pytree) -> Pytree:
    """Zero slope buffer: each leaf gets shape (s,) + leaf.shape."""
    return jax.tree_util.tree_map(
        lambda l: jnp.zeros((s,) + l.shape, l.dtype), x)


def set_stage(K: Pytree, i: int, k: Pytree) -> Pytree:
    """Write slope k into row i of the stacked buffer (static index)."""
    return jax.tree_util.tree_map(
        lambda buf, l: buf.at[i].set(l.astype(buf.dtype)), K, k)


def stage_prefix(K: Pytree, i: int) -> Pytree:
    """Rows [0, i) of the stacked buffer (static slice)."""
    return jax.tree_util.tree_map(
        lambda buf: jax.lax.slice_in_dim(buf, 0, i, axis=0), K)


def stage_suffix(K: Pytree, i: int) -> Pytree:
    """Rows [i, s) of the stacked buffer (static slice)."""
    return jax.tree_util.tree_map(
        lambda buf: jax.lax.slice_in_dim(buf, i, buf.shape[0], axis=0), K)


def append_stage(K: Pytree, k: Pytree) -> Pytree:
    """Concatenate one extra slope row (the FSAL error stage)."""
    return jax.tree_util.tree_map(
        lambda buf, l: jnp.concatenate(
            [buf, l.astype(buf.dtype)[None]], axis=0), K, k)


# ---------------------------------------------------------------------------
# Pallas leaf combines, made differentiable so the backprop / remat gradient
# modes can differentiate THROUGH the kernel calls: pallas_call has no AD
# rules, so each wrapper gets a custom JVP whose tangent is expressed in
# plain (transposable) jnp ops.  symbolic_zeros matters for memory: the
# coefficient rows are tableau constants in every solver use, so their
# tangents are symbolic zeros and the dhc·K term — the only term that would
# retain the stage buffer K as a reverse-mode residual — never enters the
# linearized graph.
# ---------------------------------------------------------------------------

def _is_zero(t) -> bool:
    return isinstance(t, jax.custom_derivatives.SymbolicZero)


@jax.custom_jvp
def _fused_axpy(base, K, hc):
    """base + sum_i hc[i] * K[i] via the Pallas kernel (one HBM pass)."""
    return ops.butcher_combine(base, K, hc, jnp.float32(1.0), use_pallas=True)


def _fused_axpy_jvp(primals, tangents):
    base, K, hc = primals
    dbase, dK, dhc = tangents
    out = ops.butcher_combine(base, K, hc, jnp.float32(1.0), use_pallas=True)
    acc_dt = jnp.promote_types(out.dtype, jnp.float32)
    dout = jnp.zeros(out.shape, acc_dt)
    if not _is_zero(dbase):
        dout = dout + dbase.astype(acc_dt)
    if not _is_zero(dK):
        for i in range(K.shape[0]):
            dout = dout + hc[i].astype(acc_dt) * dK[i].astype(acc_dt)
    if not _is_zero(dhc):
        for i in range(K.shape[0]):
            dout = dout + dhc[i].astype(acc_dt) * K[i].astype(acc_dt)
    return out, dout.astype(out.dtype)


_fused_axpy.defjvp(_fused_axpy_jvp, symbolic_zeros=True)


@jax.custom_jvp
def _fused_axpy_rows(x, K, hc, sc):
    """out[r] = sc[r]*x + sum_i hc[r, i]*K[i] via the multi-row kernel."""
    return ops.butcher_combine_rows(x, K, hc, sc, jnp.float32(1.0),
                                    use_pallas=True)


def _fused_axpy_rows_jvp(primals, tangents):
    x, K, hc, sc = primals
    dx, dK, dhc, dsc = tangents
    out = ops.butcher_combine_rows(x, K, hc, sc, jnp.float32(1.0),
                                   use_pallas=True)
    acc_dt = jnp.promote_types(out.dtype, jnp.float32)
    douts = []
    for r in range(hc.shape[0]):
        acc = jnp.zeros(x.shape, acc_dt)
        if not _is_zero(dx):
            acc = acc + sc[r].astype(acc_dt) * dx.astype(acc_dt)
        if not _is_zero(dK):
            for i in range(K.shape[0]):
                acc = acc + hc[r, i].astype(acc_dt) * dK[i].astype(acc_dt)
        if not _is_zero(dhc):
            for i in range(K.shape[0]):
                acc = acc + dhc[r, i].astype(acc_dt) * K[i].astype(acc_dt)
        if not _is_zero(dsc):
            acc = acc + dsc[r].astype(acc_dt) * x.astype(acc_dt)
        douts.append(acc)
    return out, jnp.stack(douts).astype(out.dtype)


_fused_axpy_rows.defjvp(_fused_axpy_rows_jvp, symbolic_zeros=True)


# ---------------------------------------------------------------------------
# StageCombiner
# ---------------------------------------------------------------------------

class StageCombiner:
    """All stage linear algebra for one tableau, backend-dispatched.

    Instances are cheap, stateless and cached (``get_combiner``); every
    method is traceable (h and traced coefficient rows are fine).
    """

    def __init__(self, tab: ButcherTableau, backend: str = "auto"):
        self.tab = tab
        self.backend = resolve_backend(backend)
        s = tab.s
        self.a_np = tab.a_dense
        self.b_np = tab.b_dense
        self.c_np = tab.c_dense
        self.b_err_np = tab.b_err_dense
        # I0 = {i : b_i = 0}: stages whose btilde is h_n (paper Eq. 8).
        self.i0_np = (self.b_np == 0.0).astype(np.float64)
        # Backward Lambda-recursion coefficient rows, Eq. (7)/(8) with the
        # h-dependence factored out:  coef_i(h) = R[i] + h P[i] + h^2 Q[i],
        # nonzero only for j > i.  Derivation: btilde_j = b_j + h [b_j = 0].
        R = np.zeros((s, s))
        P = np.zeros((s, s))
        Q = np.zeros((s, s))
        for i in range(s):
            for j in range(i + 1, s):
                aji = self.a_np[j, i]
                if aji == 0.0:
                    continue
                if self.b_np[i] != 0.0:
                    # -(h btilde_j) a_ji / b_i
                    P[i, j] += -aji * self.b_np[j] / self.b_np[i]
                    Q[i, j] += -aji * self.i0_np[j] / self.b_np[i]
                else:
                    # -btilde_j a_ji
                    R[i, j] += -aji * self.b_np[j]
                    P[i, j] += -aji * self.i0_np[j]
        self._lam_R, self._lam_P, self._lam_Q = R, P, Q

    # -- the one primitive everything routes through ----------------------

    def combine(self, base: Pytree, K: Pytree, coefs, h=1.0,
                idx=None) -> Pytree:
        """base + h * sum_p coefs[p] * K[idx[p]], per leaf, one fused pass.

        ``K`` is a stacked slope buffer pytree; ``coefs`` may be a static
        numpy row or a traced jnp row (the backward recursion's h-dependent
        rows).  ``idx`` (jnp backend only) maps coefficient positions to
        static buffer rows, so callers with a traced-but-statically-sparse
        row can prune dead slope-row reads at trace time; when omitted,
        coefs aligns with K's leading dim.
        """
        n_rows = int(np.shape(coefs)[0])
        if n_rows == 0:
            return base
        leaves_b, treedef = jax.tree_util.tree_flatten(base)
        leaves_K = treedef.flatten_up_to(K)
        if self.backend == "pallas":
            assert idx is None, "row pruning is a jnp-backend optimization"
            # coefficient row in the kernel's per-leaf accumulation dtype
            # (>= f32, f64 for f64 leaves): an f32 row under x64 would
            # demote the tableau coefficients the kernel multiplies by.
            out = []
            for lb, lk in zip(leaves_b, leaves_K):
                acc_dt = jnp.promote_types(lb.dtype, jnp.float32)
                hc = jnp.asarray(h, acc_dt) * jnp.asarray(coefs).astype(
                    acc_dt)
                out.append(_fused_axpy(lb, lk, hc))
        else:
            out = [self._combine_leaf_jnp(lb, lk, coefs, h, idx)
                   for lb, lk in zip(leaves_b, leaves_K)]
        return jax.tree_util.tree_unflatten(treedef, out)

    @staticmethod
    def _combine_leaf_jnp(base, K, coefs, h, idx=None):
        # accumulate in >= f32 (matches the kernel's f32 accumulate for
        # low-precision leaves) and in f64 when the state is f64, so the
        # symplectic gradient stays exact to rounding in x64 tests.
        # Unrolled over the stage dim in the kernel's order: XLA fuses the
        # chain into ONE elementwise pass over (base, K) — a tensordot
        # would lower to a degenerate (1, s) x (s, n) gemm instead.
        acc_dt = jnp.promote_types(base.dtype, jnp.float32)
        hc = jnp.asarray(h, acc_dt) * jnp.asarray(coefs).astype(acc_dt)
        # statically-zero coefficients (explicit-tableau rows are sparse,
        # e.g. dopri5's b_2 = 0) cost a slope-row read each: skip them at
        # trace time, as the pre-refactor chained AXPY did.  ``idx`` is the
        # caller-provided static sparsity pattern for traced rows.
        if idx is not None:
            pairs = [(p, int(col)) for p, col in enumerate(idx)]
        elif isinstance(coefs, np.ndarray):
            pairs = [(p, p) for p in np.nonzero(coefs)[0]]
        else:
            pairs = [(p, p) for p in range(K.shape[0])]
        acc = base.astype(acc_dt)
        for p, col in pairs:
            acc = acc + hc[p] * K[col].astype(acc_dt)
        return acc.astype(base.dtype)

    def combine_rows(self, x: Pytree, K: Pytree, rows, base_scale, h):
        """Multi-row combine: out[r] = base_scale[r]*x + h sum_i rows[r,i] K[i].

        One read of (x, K) produces all m outputs — used to fuse the step
        update and the embedded error estimate into a single pass.  Returns
        a list of m pytrees.
        """
        m = int(np.shape(rows)[0])
        leaves_x, treedef = jax.tree_util.tree_flatten(x)
        leaves_K = treedef.flatten_up_to(K)
        outs = [[] for _ in range(m)]
        for lx, lk in zip(leaves_x, leaves_K):
            if self.backend == "pallas":
                acc_dt = jnp.promote_types(lx.dtype, jnp.float32)
                hc = (jnp.asarray(h, acc_dt)
                      * jnp.asarray(rows).astype(acc_dt))
                sc = jnp.asarray(base_scale).astype(acc_dt)
                o = _fused_axpy_rows(lx, lk, hc, sc)
                for r in range(m):
                    outs[r].append(o[r])
            else:
                acc_dt = jnp.promote_types(lx.dtype, jnp.float32)
                hc = jnp.asarray(h, acc_dt) * jnp.asarray(rows).astype(acc_dt)
                sc = jnp.asarray(base_scale).astype(acc_dt)
                rows_np = rows if isinstance(rows, np.ndarray) else None
                for r in range(m):
                    acc = sc[r] * lx.astype(acc_dt)
                    idx = (np.nonzero(rows_np[r])[0] if rows_np is not None
                           else range(lk.shape[0]))
                    for i in idx:
                        acc = acc + hc[r, i] * lk[i].astype(acc_dt)
                    outs[r].append(acc.astype(lx.dtype))
        return [jax.tree_util.tree_unflatten(treedef, o) for o in outs]

    # -- forward (Eq. 5) ---------------------------------------------------

    def stage_state(self, x: Pytree, K: Pytree, h, i: int) -> Pytree:
        """X_i = x + h sum_{j<i} a_ij k_j over the buffer prefix K[:i]."""
        if i == 0 or not self.a_np[i, :i].any():
            return x
        return self.combine(x, stage_prefix(K, i), self.a_np[i, :i], h)

    def solution(self, x: Pytree, K: Pytree, h) -> Pytree:
        """x_{n+1} = x + h sum_i b_i k_i."""
        return self.combine(x, K, self.b_np, h)

    def error(self, x: Pytree, K_err: Pytree, h) -> Pytree:
        """err = h sum_i b_err_i k_i (K_err includes the FSAL slope when
        the tableau's error weights reference f(x_{n+1})).

        The pallas path reads the zeros base as a kernel operand — one
        avoidable state-sized read (~1/(s+2) of the pass) on the
        err_uses_fsal adaptive path; a base-less kernel variant could
        drop it if that path ever becomes hot.
        """
        zeros = jax.tree_util.tree_map(jnp.zeros_like, x)
        return self.combine(zeros, K_err, self.b_err_np, h)

    def solution_and_error(self, x: Pytree, K: Pytree, h):
        """(x_{n+1}, err) from ONE read of (x, K).

        Only valid when the error weights do not reference the FSAL stage
        (err_uses_fsal=False): both rows then combine the same s slopes.
        """
        assert not self.tab.err_uses_fsal and self.b_err_np is not None
        rows = np.stack([self.b_np, self.b_err_np])
        x_next, err = self.combine_rows(x, K, rows, np.array([1.0, 0.0]), h)
        return x_next, err

    # -- backward (Algorithm 2, Eq. 7/8) -----------------------------------

    def lambda_stage(self, lam_next: Pytree, L: Pytree, h, i: int) -> Pytree:
        """Lambda_{n,i} from the adjoint-slope buffer suffix L[i+1:]."""
        if self.b_np[i] != 0.0:
            base = lam_next
        else:
            base = jax.tree_util.tree_map(jnp.zeros_like, lam_next)
        s = self.tab.s
        R = self._lam_R[i, i + 1:]
        P = self._lam_P[i, i + 1:]
        Q = self._lam_Q[i, i + 1:]
        if i == s - 1 or not (R.any() or P.any() or Q.any()):
            return base
        h = jnp.asarray(h)
        if self.backend == "pallas":
            # the kernel reads the whole suffix in its single pass anyway
            row = (jnp.asarray(R) + h * jnp.asarray(P)
                   + (h * h) * jnp.asarray(Q))
            return self.combine(base, stage_suffix(L, i + 1), row, 1.0)
        # the row is traced (h-dependent) but its sparsity is static: prune
        # the structurally-dead adjoint-slope rows from the fused read.
        nz = np.nonzero((R != 0.0) | (P != 0.0) | (Q != 0.0))[0]
        row = (jnp.asarray(R[nz]) + h * jnp.asarray(P[nz])
               + (h * h) * jnp.asarray(Q[nz]))
        return self.combine(base, L, row, 1.0, idx=nz + i + 1)

    # -- dense output (4th-order Hermite interpolation) --------------------

    def interpolate(self, x0: Pytree, x1: Pytree, f0: Pytree, f1: Pytree,
                    h, theta) -> Pytree:
        """Cubic-Hermite dense output x(t_n + theta h) over one step.

        ``x0``/``x1`` are the step endpoints, ``f0``/``f1`` their slopes,
        ``theta`` in [0, 1] the (traced) interpolation parameter.  The
        interpolant is evaluated as ONE row combine over the stacked buffer
        [f0, f1, x1 - x0] with the traced coefficient row
        ``HERMITE_DENSE_W @ [1, theta, theta^2, theta^3]`` — the same fused
        one-HBM-pass primitive (jnp oracle or Pallas kernel) as every
        Butcher row.  Local error O(h^4).
        """
        h = jnp.asarray(h)
        theta = jnp.asarray(theta)
        powers = jnp.stack([jnp.ones_like(theta), theta,
                            theta * theta, theta ** 3])
        w = jnp.asarray(HERMITE_DENSE_W) @ powers          # (3,)
        # fold h into the slope rows so combine's h factor can stay 1:
        # out = x0 + (h w0) f0 + (h w1) f1 + w2 (x1 - x0)
        row = jnp.stack([h * w[0], h * w[1], w[2]])
        D = jax.tree_util.tree_map(
            lambda a, b, g0, g1: jnp.stack([g0.astype(a.dtype),
                                            g1.astype(a.dtype),
                                            b - a]), x0, x1, f0, f1)
        return self.combine(x0, D, row, 1.0)

    def lambda_update(self, lam_next: Pytree, L: Pytree, h) -> Pytree:
        """lambda_n = lambda_{n+1} - h sum_i btilde_i l_{n,i}."""
        h = jnp.asarray(h)
        coefs = -(jnp.asarray(self.b_np) + h * jnp.asarray(self.i0_np))
        return self.combine(lam_next, L, coefs, h)


@functools.lru_cache(maxsize=None)
def get_combiner(tab: ButcherTableau,
                 backend: str = "auto") -> StageCombiner:
    return StageCombiner(tab, backend)
