"""Butcher tableaus for explicit Runge-Kutta methods.

Every tableau is explicit (a[i][j] == 0 for j >= i).  ``b_err`` (when present)
is the embedded lower-order weight vector used for adaptive step control; for
DOP853 the error weights reference an extra FSAL-style stage k_{s+1} =
f(x_{n+1}), flagged by ``err_uses_fsal``.

The symplectic adjoint method (core/symplectic.py) consumes ``a``, ``b``, ``c``
directly and handles b_i == 0 stages via the paper's Eq. (7)/(8) I0 set.
"""
from __future__ import annotations

import dataclasses
import functools
from fractions import Fraction
from typing import Optional, Tuple

import numpy as np

__all__ = ["ButcherTableau", "get_tableau", "TABLEAUS", "register_tableau",
           "HERMITE_DENSE_W"]

# ---------------------------------------------------------------------------
# Dense-output (interpolation) tableau.
#
# The adaptive driver observes interior times via 4th-order cubic-Hermite
# dense output over each accepted step [t_n, t_n + h_n].  With theta in
# [0, 1] the interpolant is
#
#   x(t_n + theta h) = x_n + h [w0(theta) f_n + w1(theta) f_{n+1}]
#                          + w2(theta) (x_{n+1} - x_n),
#
# where (w0, w1, w2) are the Hermite basis polynomials h10, h11, h01.  The
# rows of HERMITE_DENSE_W give their monomial coefficients against
# [1, theta, theta^2, theta^3], so the combine row for a given theta is
# ``HERMITE_DENSE_W @ [1, theta, theta^2, theta^3]`` — evaluated traced and
# fed to the StageCombiner row-combine primitive exactly like a Butcher row
# (core/combine.py::StageCombiner.interpolate).  Local error is O(h^4) for
# any tableau of order >= 3 (the interpolant only consumes the step
# endpoints and their slopes, so it is tableau-independent).
# ---------------------------------------------------------------------------

HERMITE_DENSE_W = np.array([
    [0.0, 1.0, -2.0, 1.0],   # w0 = h10(theta) = theta - 2 theta^2 + theta^3
    [0.0, 0.0, -1.0, 1.0],   # w1 = h11(theta) = -theta^2 + theta^3
    [0.0, 0.0, 3.0, -2.0],   # w2 = h01(theta) = 3 theta^2 - 2 theta^3
], dtype=np.float64)


@dataclasses.dataclass(frozen=True)
class ButcherTableau:
    name: str
    order: int
    a: Tuple[Tuple[float, ...], ...]  # s rows; row i has entries a[i][j], j<i
    b: Tuple[float, ...]
    c: Tuple[float, ...]
    b_err: Optional[Tuple[float, ...]] = None  # embedded error weights (b - b*)
    err_order: Optional[int] = None
    err_uses_fsal: bool = False  # b_err has s+1 entries, last for f(x_{n+1})
    fsal: bool = False  # last stage of step n == first stage of step n+1

    @property
    def s(self) -> int:
        return len(self.b)

    @property
    def n_fevals(self) -> int:
        """Effective function evaluations per step (FSAL reuses one)."""
        return self.s - 1 if self.fsal else self.s

    def __post_init__(self):
        s = len(self.b)
        assert len(self.c) == s, (self.name, "c length")
        assert len(self.a) == s, (self.name, "a rows")
        for i, row in enumerate(self.a):
            assert len(row) == s, (self.name, "a row length", i)
            for j in range(i, s):
                assert row[j] == 0.0, (self.name, "not explicit", i, j)
        if self.b_err is not None:
            expect = s + 1 if self.err_uses_fsal else s
            assert len(self.b_err) == expect, (self.name, "b_err length")

    def a_np(self, dtype=np.float64) -> np.ndarray:
        return np.array(self.a, dtype=dtype)

    def b_np(self, dtype=np.float64) -> np.ndarray:
        return np.array(self.b, dtype=dtype)

    def c_np(self, dtype=np.float64) -> np.ndarray:
        return np.array(self.c, dtype=dtype)

    # Dense coefficient arrays alongside the Python tuples.  The solver
    # stack (core/combine.py) consumes these; they are host-side numpy so
    # they enter jit traces as constants in whatever precision the trace
    # runs at (f64 under jax_enable_x64, f32 otherwise).  cached_property
    # writes straight to __dict__, which bypasses the frozen-dataclass
    # __setattr__ guard, so each array is built once per tableau.

    @functools.cached_property
    def a_dense(self) -> np.ndarray:
        return np.array(self.a, dtype=np.float64)

    @functools.cached_property
    def b_dense(self) -> np.ndarray:
        return np.array(self.b, dtype=np.float64)

    @functools.cached_property
    def c_dense(self) -> np.ndarray:
        return np.array(self.c, dtype=np.float64)

    @functools.cached_property
    def b_err_dense(self) -> Optional[np.ndarray]:
        if self.b_err is None:
            return None
        return np.array(self.b_err, dtype=np.float64)


def _frac_rows(rows, s):
    """Pad variable-length lower-triangular rows with zeros to s columns."""
    out = []
    for row in rows:
        vals = [float(Fraction(x) if isinstance(x, str) else x) for x in row]
        vals = vals + [0.0] * (s - len(vals))
        out.append(tuple(vals))
    return tuple(out)


def _fr(seq):
    return tuple(float(Fraction(x) if isinstance(x, str) else x) for x in seq)


TABLEAUS = {}


def register_tableau(t: ButcherTableau) -> ButcherTableau:
    TABLEAUS[t.name] = t
    return t


# --- Euler (order 1, s=1) ---------------------------------------------------
register_tableau(ButcherTableau(
    name="euler", order=1,
    a=((0.0,),), b=(1.0,), c=(0.0,),
))

# --- Midpoint (order 2, s=2) ------------------------------------------------
register_tableau(ButcherTableau(
    name="midpoint", order=2,
    a=_frac_rows([[], ["1/2"]], 2),
    b=_fr(["0", "1"]), c=_fr(["0", "1/2"]),
))

# --- Heun-Euler (adaptive heun; order 2(1), s=2) -----------------------------
register_tableau(ButcherTableau(
    name="heun12", order=2,
    a=_frac_rows([[], ["1"]], 2),
    b=_fr(["1/2", "1/2"]), c=_fr(["0", "1"]),
    b_err=_fr(["-1/2", "1/2"]), err_order=1,
))

# --- Bogacki-Shampine (bosh3; order 3(2), s=4 with FSAL, b4=0) ---------------
register_tableau(ButcherTableau(
    name="bosh3", order=3,
    a=_frac_rows([[], ["1/2"], ["0", "3/4"], ["2/9", "1/3", "4/9"]], 4),
    b=_fr(["2/9", "1/3", "4/9", "0"]),
    c=_fr(["0", "1/2", "3/4", "1"]),
    b_err=_fr([str(Fraction(2, 9) - Fraction(7, 24)),
               str(Fraction(1, 3) - Fraction(1, 4)),
               str(Fraction(4, 9) - Fraction(1, 3)),
               str(Fraction(0) - Fraction(1, 8))]),
    err_order=2, fsal=True,
))

# --- Classic RK4 (order 4, s=4) ----------------------------------------------
register_tableau(ButcherTableau(
    name="rk4", order=4,
    a=_frac_rows([[], ["1/2"], ["0", "1/2"], ["0", "0", "1"]], 4),
    b=_fr(["1/6", "1/3", "1/3", "1/6"]),
    c=_fr(["0", "1/2", "1/2", "1"]),
))

# --- Fehlberg 4(5) (order 5 weights used; s=6) --------------------------------
_fb = {
    "b5": ["16/135", "0", "6656/12825", "28561/56430", "-9/50", "2/55"],
    "b4": ["25/216", "0", "1408/2565", "2197/4104", "-1/5", "0"],
}
register_tableau(ButcherTableau(
    name="fehlberg45", order=5,
    a=_frac_rows([
        [],
        ["1/4"],
        ["3/32", "9/32"],
        ["1932/2197", "-7200/2197", "7296/2197"],
        ["439/216", "-8", "3680/513", "-845/4104"],
        ["-8/27", "2", "-3544/2565", "1859/4104", "-11/40"],
    ], 6),
    b=_fr(_fb["b5"]),
    c=_fr(["0", "1/4", "3/8", "12/13", "1", "1/2"]),
    b_err=tuple(float(Fraction(x5) - Fraction(x4))
                for x5, x4 in zip(_fb["b5"], _fb["b4"])),
    err_order=4,
))

# --- Dormand-Prince 5(4) (dopri5; s=7 with FSAL, b2=b7=0 handled by I0) -------
_dp_b = ["35/384", "0", "500/1113", "125/192", "-2187/6784", "11/84", "0"]
_dp_bstar = ["5179/57600", "0", "7571/16695", "393/640",
             "-92097/339200", "187/2100", "1/40"]
register_tableau(ButcherTableau(
    name="dopri5", order=5,
    a=_frac_rows([
        [],
        ["1/5"],
        ["3/40", "9/40"],
        ["44/45", "-56/15", "32/9"],
        ["19372/6561", "-25360/2187", "64448/6561", "-212/729"],
        ["9017/3168", "-355/33", "46732/5247", "49/176", "-5103/18656"],
        ["35/384", "0", "500/1113", "125/192", "-2187/6784", "11/84"],
    ], 7),
    b=_fr(_dp_b),
    c=_fr(["0", "1/5", "3/10", "4/5", "8/9", "1", "1"]),
    b_err=tuple(float(Fraction(x) - Fraction(y))
                for x, y in zip(_dp_b, _dp_bstar)),
    err_order=4, fsal=True,
))


# --- Dormand-Prince 8 (DOP853 core; s=12, order 8) ---------------------------
def _register_dopri8():
    try:
        from scipy.integrate._ivp import dop853_coefficients as dc
    except Exception:  # pragma: no cover - scipy always present in this env
        return
    s = int(dc.N_STAGES)  # 12
    A = np.asarray(dc.A, dtype=np.float64)[:s, :s]
    B = np.asarray(dc.B, dtype=np.float64)[:s]
    C = np.asarray(dc.C, dtype=np.float64)[:s]
    E5 = np.asarray(dc.E5, dtype=np.float64)[:s + 1]  # 5th-order err, uses f_new
    a = tuple(tuple(float(A[i, j]) if j < i else 0.0 for j in range(s))
              for i in range(s))
    register_tableau(ButcherTableau(
        name="dopri8", order=8,
        a=a, b=tuple(float(x) for x in B), c=tuple(float(x) for x in C),
        b_err=tuple(float(x) for x in E5), err_order=5, err_uses_fsal=True,
    ))


_register_dopri8()


def get_tableau(name: str) -> ButcherTableau:
    if name not in TABLEAUS:
        raise KeyError(f"unknown tableau {name!r}; have {sorted(TABLEAUS)}")
    return TABLEAUS[name]
