"""Composable solve API: solver x gradient x stepping x observation.

The paper's contribution is a *gradient strategy* — the symplectic adjoint —
that composes orthogonally with the solver tableau, the step controller, and
the observation scheme.  This module makes each axis a first-class object and
gives them a single entry point:

    sol = solve(f, x0, params,
                saveat=SaveAt(ts=jnp.linspace(0.1, 1.0, 64)),
                method="dopri5",
                gradient=SymplecticAdjoint(),
                stepping=AdaptiveConfig(rtol=1e-6, atol=1e-8))
    sol.ys           # observations (stacked over SaveAt.ts) or final state
    sol.stats        # n_steps / n_fevals / n_attempts (non-differentiated)
    sol.success      # bool: adaptive budgets were sufficient
    sol.final_state  # the state at the end of integration

``Solution`` is a registered pytree, so the one call shape works unchanged
under ``jit``, ``vmap`` (batched ``x0``), and ``grad`` (losses on ``sol.ys``;
stats ride along as integer auxiliaries that autodiff never touches, and XLA
dead-code-eliminates their computation under ``jit`` when they go unused).
Strategies whose drivers expose the controller counters serve value and
stats from one run; for the custom-VJP strategies the adaptive stats come
from a stop_gradient controller replay — free under ``jit`` (CSE/DCE), a
real second integration in eager adaptive solves (docs/api.md, Cost note).

Gradient strategies are frozen dataclasses carrying their own knobs:

    SymplecticAdjoint()                  — the paper: exact gradient,
                                           memory O(N + s + L)    [default]
    DirectBackprop()                     — differentiate through the solver:
                                           exact gradient, memory O(N s L)
    RematStep()                          — ANODE/ACA step checkpointing:
                                           exact gradient, memory O(N + s L)
    RematSolve()                         — whole-solve rematerialization:
                                           exact, memory O(N s L) in bwd
    ContinuousAdjoint(steps_multiplier=...,
                      bwd_adaptive=...)  — Chen et al. 2018: approximate
                                           gradient, memory O(L)

Each strategy registers itself in ``GRADIENT_REGISTRY`` under a short name
(``register_gradient``); a sixth scheme is one subclass away — ``solve`` never
grows another ``elif`` (tests/test_api.py registers a toy strategy to prove
it).  Which (stepping, saveat) cells a strategy supports is declared on the
class as a ``capabilities`` frozenset; ``capability_matrix()`` assembles the
full declarative table (rendered in docs/api.md) and every illegal combination
fails with the same uniformly-shaped ``ValueError``.

``SaveAt`` chooses the observation scheme: ``SaveAt(t1=...)`` returns the
final state; ``SaveAt(ts=...)`` observes at each time in ``ts`` by
checkpointed segmentation (exact discrete gradients, any strategy that
supports it); ``SaveAt(ts=..., dense=True)`` runs ONE unsegmented adaptive
solve and interpolates with 4th-order Hermite dense output (the controller
never sees the observation times; DirectBackprop only).  ``ts`` must be
monotone in the direction of integration — duplicates are allowed
(zero-length segments), and concrete non-monotone arrays are rejected
eagerly at trace time.

``stepping`` is either an ``int`` (fixed grid, N equal steps — per segment
when observing) or an ``AdaptiveConfig`` (PI-controlled adaptive stepping,
``max_steps`` per segment).

``batch_axis=0`` declares the leading axis of every state leaf a batch of
INDEPENDENT trajectories: adaptive solves then run masked per-lane step
control (each lane its own error norm, accept/reject, and accepted grid —
no cross-lane coupling) in one fused while_loop, ``stats``/``success``
become per-lane (B,) arrays, and the symplectic/continuous adjoints replay
each lane's own grid, so batched gradients match a loop of single solves
to rounding (docs/batching.md; ``batched_capability_matrix()`` declares
which cells support it).

The legacy ``odeint`` / ``odeint_with_stats`` front-ends survive as thin
deprecation shims over ``solve`` (core/odeint.py); docs/api.md carries the
old-kwarg -> new-object migration table.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, ClassVar, Dict, FrozenSet, Optional, Tuple, Type, Union

import jax
import jax.numpy as jnp
import numpy as np

from .adjoint import (odeint_adjoint, odeint_adjoint_adaptive,
                      odeint_adjoint_adaptive_batched)
from .backprop import odeint_backprop, odeint_remat_solve, odeint_remat_step
from .combine import resolve_backend
from .rk import (AdaptiveConfig, VectorField, apply_on_failure,
                 apply_on_failure_lanes, hermite_observe, lane_count,
                 rk_solve_adaptive, rk_solve_adaptive_batched,
                 rk_solve_adaptive_batched_saveat_stacked,
                 rk_solve_adaptive_saveat_stacked, rk_solve_fixed,
                 segment_starts)
from .symplectic import (odeint_symplectic, odeint_symplectic_adaptive,
                         odeint_symplectic_adaptive_batched,
                         odeint_symplectic_saveat,
                         odeint_symplectic_saveat_adaptive,
                         odeint_symplectic_saveat_adaptive_batched)
from .tableau import ButcherTableau, get_tableau

Pytree = Any

STEPPING_KINDS = ("fixed", "adaptive")
SAVEAT_KINDS = ("t1", "ts", "dense")


# ---------------------------------------------------------------------------
# SaveAt: what to observe
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SaveAt:
    """Observation scheme: exactly one of ``t1`` (final state) or ``ts``
    (stacked observations; the solve ends at ``ts[-1]``).

    ``dense=True`` selects Hermite dense-output interpolation at ``ts``
    instead of checkpointed segmentation (adaptive solves only; the step
    controller never sees the observation times)."""
    t1: Optional[Any] = None
    ts: Optional[Any] = None
    dense: bool = False

    def __post_init__(self):
        if self.t1 is not None and self.ts is not None:
            raise ValueError(
                "pass EITHER t1 or ts: with observation times the solve "
                "ends at ts[-1] (include the end time in ts)")
        if self.t1 is None and self.ts is None:
            raise ValueError("SaveAt needs one of t1=... or ts=...")
        if self.dense and self.ts is None:
            raise ValueError("SaveAt(dense=True) needs observation times "
                             "ts=..., not t1")

    @property
    def kind(self) -> str:
        if self.ts is None:
            return "t1"
        return "dense" if self.dense else "ts"


def _as_ts(ts, dtype, t0=None) -> jnp.ndarray:
    """Validate and coerce observation times.

    Enforces the documented monotonicity contract eagerly wherever the
    values are concrete (trace-time check; tracers — e.g. under vmap over
    ts — are passed through).  Duplicates are legal zero-length segments;
    descending ts is legal reverse-time integration, but the direction must
    be consistent across [t0, ts[0], ..., ts[-1]]."""
    ts = jnp.asarray(ts, dtype=dtype)
    if ts.ndim != 1 or ts.shape[0] == 0:
        raise ValueError("ts must be a non-empty 1-D array of observation "
                         f"times; got shape {ts.shape}")
    if not isinstance(ts, jax.core.Tracer):
        seq = np.asarray(ts)
        if t0 is not None and not isinstance(t0, jax.core.Tracer):
            seq = np.concatenate([np.reshape(np.asarray(t0), (1,)), seq])
        d = np.diff(seq)
        if not (np.all(d >= 0) or np.all(d <= 0)):
            raise ValueError(
                "ts must be monotone in the direction of integration "
                "(duplicates are allowed; descending ts is reverse-time); "
                f"got t0={None if t0 is None else np.asarray(t0)} "
                f"ts={np.asarray(ts)}")
    return ts


# ---------------------------------------------------------------------------
# Solution: the one return shape
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Solution:
    """Result of ``solve``: a registered pytree.

    ys          — the observed solution: stacked over ``SaveAt.ts`` (leading
                  axis len(ts) per leaf) or the final state for ``SaveAt.t1``.
                  Differentiable under the selected gradient strategy.
    final_state — the state at the end of integration (== ``ys`` for t1;
                  the last observation for ts).
    stats       — {"n_steps", "n_fevals", "n_attempts"}: int32 counters of
                  the realized solve.  Exact static counts on fixed grids;
                  the controller's realized counters on adaptive solves.
                  Scalars for a single trajectory; per-lane (B,) arrays
                  under ``solve(..., batch_axis=0)``.  Never
                  differentiated; dead-code-eliminated under jit when
                  unused.
    success     — bool: the solve reached its target time within the
                  adaptive budgets (always True on fixed grids).  Per-lane
                  (B,) under ``batch_axis=0`` — one stiff lane failing
                  does not flag (or poison) its batchmates.
    """
    ys: Pytree
    final_state: Pytree
    stats: Dict[str, jnp.ndarray]
    success: jnp.ndarray

    def tree_flatten(self):
        return ((self.ys, self.final_state, self.stats, self.success), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


# ---------------------------------------------------------------------------
# Gradient strategies
# ---------------------------------------------------------------------------

class _Ctx:
    """Static per-solve context handed to every strategy hook."""
    __slots__ = ("f", "tab", "n_steps", "adaptive", "backend")

    def __init__(self, f: VectorField, tab: ButcherTableau,
                 n_steps: Optional[int], adaptive: Optional[AdaptiveConfig],
                 backend: str):
        self.f = f
        self.tab = tab
        self.n_steps = n_steps
        self.adaptive = adaptive
        self.backend = backend


def _segmented(solve_one: Callable, x0, t0, ts):
    """Generic SaveAt segmentation: chain per-segment solves inside ONE
    lax.scan, stacking the segment endpoints.  Observation cotangents are
    injected at the boundaries automatically by reverse-mode through the
    composition; trace/jaxpr size is O(1) in len(ts) (docs/adaptive.md)."""
    def body(x, seg):
        a, b = seg
        x = solve_one(x, a, b)
        return x, x

    _, obs = jax.lax.scan(body, x0, (segment_starts(t0, ts), ts))
    return obs


_FIXED_T1 = ("fixed", "t1")
_FIXED_TS = ("fixed", "ts")
_ADAPT_T1 = ("adaptive", "t1")
_ADAPT_TS = ("adaptive", "ts")
_ADAPT_DENSE = ("adaptive", "dense")


class GradientStrategy:
    """Base class for gradient strategies.

    A strategy declares its legal (stepping, saveat) cells in
    ``capabilities`` and implements the value hooks for the cells it
    supports; the SaveAt hooks default to generic checkpointed segmentation
    over the plain solves, and the stats hooks default to a non-
    differentiated controller replay — so a minimal new strategy is
    ``name`` + ``capabilities`` + ``fixed`` (and ``adaptive`` if claimed).
    Register it with ``@register_gradient``; ``solve`` needs no edits.
    """
    name: ClassVar[str]
    capabilities: ClassVar[FrozenSet[Tuple[str, str]]]
    # adaptive cells ALSO legal under ``solve(..., batch_axis=0)`` — i.e.
    # cells for which the strategy has a masked per-lane batched driver.
    # Fixed-grid cells never appear here: a fixed grid is state-independent,
    # so every claimed fixed cell is batchable for free (``batched_cells``).
    batched_capabilities: ClassVar[FrozenSet[Tuple[str, str]]] = frozenset()

    @classmethod
    def batched_cells(cls) -> FrozenSet[Tuple[str, str]]:
        """(stepping, saveat) cells legal with ``batch_axis=0``: every fixed
        cell the strategy claims (the grid cannot depend on the state, so
        batch-in-state already IS per-lane exact) plus the declared
        ``batched_capabilities`` adaptive cells."""
        fixed = frozenset(c for c in cls.capabilities if c[0] == "fixed")
        return fixed | cls.batched_capabilities

    # -- value hooks --------------------------------------------------------
    def fixed(self, ctx: _Ctx, x0, t0, t1, params):
        raise NotImplementedError

    def adaptive(self, ctx: _Ctx, x0, t0, t1, params):
        raise NotImplementedError

    def fixed_saveat(self, ctx: _Ctx, x0, t0, ts, params):
        return _segmented(lambda x, a, b: self.fixed(ctx, x, a, b, params),
                          x0, t0, ts)

    def adaptive_saveat(self, ctx: _Ctx, x0, t0, ts, params):
        return _segmented(
            lambda x, a, b: self.adaptive(ctx, x, a, b, params), x0, t0, ts)

    # -- stats hooks (non-differentiated controller replays) ----------------
    def adaptive_stats(self, ctx: _Ctx, x0, t0, t1, params):
        """Counters of the realized adaptive solve.  Default: replay the
        controller once under stop_gradient with the exact arguments every
        driver's forward pass uses — the counters match the value solve
        bit-for-bit, and under jit XLA CSE/DCE collapses the duplicate."""
        sol = rk_solve_adaptive(ctx.f, ctx.tab, jax.lax.stop_gradient(x0),
                                t0, t1, jax.lax.stop_gradient(params),
                                ctx.adaptive, ctx.backend)
        return ({"n_steps": sol.n_accepted, "n_fevals": sol.n_fevals,
                 "n_attempts": sol.n_attempts}, sol.succeeded)

    def adaptive_saveat_stats(self, ctx: _Ctx, x0, t0, ts, params):
        """Default segmented replay RESTARTS the controller at every
        observation boundary — exactly the step sequence the default
        ``adaptive_saveat`` (generic segmentation over ``adaptive``)
        realizes.  Strategies whose SaveAt drivers thread the controller
        step across boundaries (symplectic, backprop) override this with
        the threaded stacked replay so stats and value always describe the
        SAME solve."""
        cfg = ctx.adaptive
        x0 = jax.lax.stop_gradient(x0)
        params = jax.lax.stop_gradient(params)

        def body(x, seg):
            a, b = seg
            sol = rk_solve_adaptive(ctx.f, ctx.tab, x, a, b, params, cfg,
                                    ctx.backend)
            x = apply_on_failure(sol.x_final, sol.succeeded, cfg.on_failure)
            return x, (sol.n_accepted, sol.n_fevals, sol.n_attempts,
                       sol.succeeded)

        _, (na, nf, nt, ok) = jax.lax.scan(body, x0,
                                           (segment_starts(t0, ts), ts))
        return ({"n_steps": jnp.sum(na), "n_fevals": jnp.sum(nf),
                 "n_attempts": jnp.sum(nt)}, jnp.all(ok))

    # -- combined value+stats hooks (what ``solve`` actually calls) ---------
    def adaptive_with_stats(self, ctx: _Ctx, x0, t0, t1, params):
        """Value + stats for an adaptive t1 solve.  Strategies whose value
        driver already exposes the controller counters override this to a
        single run (DirectBackprop); custom-VJP strategies keep the
        default value-hook + replay pair."""
        ys = self.adaptive(ctx, x0, t0, t1, params)
        stats, success = self.adaptive_stats(ctx, x0, t0, t1, params)
        return ys, stats, success

    def adaptive_saveat_with_stats(self, ctx: _Ctx, x0, t0, ts, params):
        ys = self.adaptive_saveat(ctx, x0, t0, ts, params)
        stats, success = self.adaptive_saveat_stats(ctx, x0, t0, ts, params)
        return ys, stats, success

    def dense_saveat_with_stats(self, ctx: _Ctx, x0, t0, ts, params):
        """Dense-output observation.  NOTE: unlike the plain value hooks
        this returns the (ys, stats, success) triple — dense output and
        its controller run are inseparable, so there is no value-only
        form.  Unreachable unless the strategy claims ('adaptive',
        'dense')."""
        raise NotImplementedError

    # -- batched hooks (masked per-lane adaptive control, batch_axis=0) -----
    # Stats and success are PER LANE: (B,) int32 / bool arrays.
    def adaptive_batched(self, ctx: _Ctx, x0, t0, t1, params):
        raise NotImplementedError

    def adaptive_saveat_batched(self, ctx: _Ctx, x0, t0, ts, params):
        return _segmented(
            lambda x, a, b: self.adaptive_batched(ctx, x, a, b, params),
            x0, t0, ts)

    def adaptive_batched_stats(self, ctx: _Ctx, x0, t0, t1, params):
        """Per-lane counters of the realized batched solve (stop_gradient
        controller replay, exactly like ``adaptive_stats``)."""
        sol = rk_solve_adaptive_batched(
            ctx.f, ctx.tab, jax.lax.stop_gradient(x0), t0, t1,
            jax.lax.stop_gradient(params), ctx.adaptive, ctx.backend)
        return ({"n_steps": sol.n_accepted, "n_fevals": sol.n_fevals,
                 "n_attempts": sol.n_attempts}, sol.succeeded)

    def adaptive_saveat_batched_stats(self, ctx: _Ctx, x0, t0, ts, params):
        """Restart-per-segment batched replay, matching the step sequence
        the default ``adaptive_saveat_batched`` (generic segmentation over
        ``adaptive_batched``) realizes.  Strategies whose batched SaveAt
        drivers thread the per-lane controller step across boundaries
        override with the threaded stacked replay."""
        cfg = ctx.adaptive
        x0 = jax.lax.stop_gradient(x0)
        params = jax.lax.stop_gradient(params)

        def body(x, seg):
            a, b = seg
            sol = rk_solve_adaptive_batched(ctx.f, ctx.tab, x, a, b, params,
                                            cfg, ctx.backend)
            x = apply_on_failure_lanes(sol.x_final, sol.succeeded,
                                       cfg.on_failure)
            return x, (sol.n_accepted, sol.n_fevals, sol.n_attempts,
                       sol.succeeded)

        _, (na, nf, nt, ok) = jax.lax.scan(body, x0,
                                           (segment_starts(t0, ts), ts))
        return ({"n_steps": jnp.sum(na, axis=0),
                 "n_fevals": jnp.sum(nf, axis=0),
                 "n_attempts": jnp.sum(nt, axis=0)}, jnp.all(ok, axis=0))

    def adaptive_batched_with_stats(self, ctx: _Ctx, x0, t0, t1, params):
        ys = self.adaptive_batched(ctx, x0, t0, t1, params)
        stats, success = self.adaptive_batched_stats(ctx, x0, t0, t1, params)
        return ys, stats, success

    def adaptive_saveat_batched_with_stats(self, ctx: _Ctx, x0, t0, ts,
                                           params):
        ys = self.adaptive_saveat_batched(ctx, x0, t0, ts, params)
        stats, success = self.adaptive_saveat_batched_stats(
            ctx, x0, t0, ts, params)
        return ys, stats, success


def _threaded_saveat_batched_stats(ctx: _Ctx, x0, t0, ts, params):
    """Per-lane stats replay for batched SaveAt drivers that thread each
    lane's controller step across observation boundaries."""
    _, sols = rk_solve_adaptive_batched_saveat_stacked(
        ctx.f, ctx.tab, jax.lax.stop_gradient(x0), t0, ts,
        jax.lax.stop_gradient(params), ctx.adaptive, ctx.backend)
    return ({"n_steps": jnp.sum(sols.n_accepted, axis=0),
             "n_fevals": jnp.sum(sols.n_fevals, axis=0),
             "n_attempts": jnp.sum(sols.n_attempts, axis=0)},
            jnp.all(sols.succeeded, axis=0))


def _threaded_saveat_stats(ctx: _Ctx, x0, t0, ts, params):
    """Stats replay for SaveAt drivers that THREAD the controller step
    across observation boundaries (the stacked-scan segmentation the
    symplectic and backprop drivers use)."""
    _, sols = rk_solve_adaptive_saveat_stacked(
        ctx.f, ctx.tab, jax.lax.stop_gradient(x0), t0, ts,
        jax.lax.stop_gradient(params), ctx.adaptive, ctx.backend)
    return ({"n_steps": jnp.sum(sols.n_accepted),
             "n_fevals": jnp.sum(sols.n_fevals),
             "n_attempts": jnp.sum(sols.n_attempts)},
            jnp.all(sols.succeeded))


GRADIENT_REGISTRY: Dict[str, Type[GradientStrategy]] = {}


def register_gradient(cls: Type[GradientStrategy]) -> Type[GradientStrategy]:
    """Class decorator: register a strategy under ``cls.name``.

    ``as_gradient(name)`` then resolves the name to a default-constructed
    instance; ``solve`` dispatches purely through the strategy interface,
    so registration is the ONLY integration point a new scheme needs."""
    GRADIENT_REGISTRY[cls.name] = cls
    return cls


def as_gradient(spec: Union[str, GradientStrategy,
                            Type[GradientStrategy]]) -> GradientStrategy:
    """Coerce a strategy instance / class / registered name to an instance."""
    if isinstance(spec, GradientStrategy):
        return spec
    if isinstance(spec, type) and issubclass(spec, GradientStrategy):
        return spec()
    if isinstance(spec, str):
        if spec not in GRADIENT_REGISTRY:
            raise ValueError(
                f"unknown gradient strategy {spec!r}; registered strategies: "
                f"{sorted(GRADIENT_REGISTRY)}")
        return GRADIENT_REGISTRY[spec]()
    raise TypeError(
        "gradient must be a GradientStrategy instance, a GradientStrategy "
        f"subclass, or a registered name; got {type(spec).__name__}")


@register_gradient
@dataclasses.dataclass(frozen=True)
class SymplecticAdjoint(GradientStrategy):
    """The paper's method: exact gradient of the discrete forward map with
    O(N + s + L) memory (Algorithm 2 backward from per-step checkpoints)."""
    name: ClassVar[str] = "symplectic"
    capabilities: ClassVar[FrozenSet] = frozenset(
        {_FIXED_T1, _FIXED_TS, _ADAPT_T1, _ADAPT_TS})
    batched_capabilities: ClassVar[FrozenSet] = frozenset(
        {_ADAPT_T1, _ADAPT_TS})

    def fixed(self, ctx, x0, t0, t1, params):
        return odeint_symplectic(ctx.f, ctx.tab, ctx.n_steps, ctx.backend,
                                 x0, t0, t1, params)

    def adaptive(self, ctx, x0, t0, t1, params):
        return odeint_symplectic_adaptive(ctx.f, ctx.tab, ctx.adaptive,
                                          ctx.backend, x0, t0, t1, params)

    def fixed_saveat(self, ctx, x0, t0, ts, params):
        return odeint_symplectic_saveat(ctx.f, ctx.tab, ctx.n_steps,
                                        ctx.backend, x0, t0, ts, params)

    def adaptive_saveat(self, ctx, x0, t0, ts, params):
        return odeint_symplectic_saveat_adaptive(
            ctx.f, ctx.tab, ctx.adaptive, ctx.backend, x0, t0, ts, params)

    def adaptive_saveat_stats(self, ctx, x0, t0, ts, params):
        return _threaded_saveat_stats(ctx, x0, t0, ts, params)

    # batched: exact per-lane gradients replaying each lane's own grid
    def adaptive_batched(self, ctx, x0, t0, t1, params):
        return odeint_symplectic_adaptive_batched(
            ctx.f, ctx.tab, ctx.adaptive, ctx.backend, x0, t0, t1, params)

    def adaptive_saveat_batched(self, ctx, x0, t0, ts, params):
        return odeint_symplectic_saveat_adaptive_batched(
            ctx.f, ctx.tab, ctx.adaptive, ctx.backend, x0, t0, ts, params)

    def adaptive_saveat_batched_stats(self, ctx, x0, t0, ts, params):
        return _threaded_saveat_batched_stats(ctx, x0, t0, ts, params)


@register_gradient
@dataclasses.dataclass(frozen=True)
class DirectBackprop(GradientStrategy):
    """Differentiate through the solver (exact; memory O(N s L)).  Adaptive
    solves are forward-value/JVP only (reverse-mode cannot cross the
    lax.while_loop); the only strategy supporting dense output."""
    name: ClassVar[str] = "backprop"
    capabilities: ClassVar[FrozenSet] = frozenset(
        {_FIXED_T1, _FIXED_TS, _ADAPT_T1, _ADAPT_TS, _ADAPT_DENSE})
    batched_capabilities: ClassVar[FrozenSet] = frozenset(
        {_ADAPT_T1, _ADAPT_TS})

    def fixed(self, ctx, x0, t0, t1, params):
        return odeint_backprop(ctx.f, ctx.tab, ctx.n_steps, x0, t0, t1,
                               params, ctx.backend)

    def adaptive(self, ctx, x0, t0, t1, params):
        sol = rk_solve_adaptive(ctx.f, ctx.tab, x0, t0, t1, params,
                                ctx.adaptive, ctx.backend)
        return apply_on_failure(sol.x_final, sol.succeeded,
                                ctx.adaptive.on_failure)

    def adaptive_saveat(self, ctx, x0, t0, ts, params):
        obs, _ = rk_solve_adaptive_saveat_stacked(
            ctx.f, ctx.tab, x0, t0, ts, params, ctx.adaptive, ctx.backend)
        return obs

    # the value drivers above ARE the controller, so value and stats come
    # from ONE run — no replay (this is also what keeps the
    # odeint_with_stats shim at its historical single-solve cost).
    def adaptive_with_stats(self, ctx, x0, t0, t1, params):
        sol = rk_solve_adaptive(ctx.f, ctx.tab, x0, t0, t1, params,
                                ctx.adaptive, ctx.backend)
        ys = apply_on_failure(sol.x_final, sol.succeeded,
                              ctx.adaptive.on_failure)
        return ys, {"n_steps": sol.n_accepted, "n_fevals": sol.n_fevals,
                    "n_attempts": sol.n_attempts}, sol.succeeded

    def adaptive_saveat_with_stats(self, ctx, x0, t0, ts, params):
        obs, sols = rk_solve_adaptive_saveat_stacked(
            ctx.f, ctx.tab, x0, t0, ts, params, ctx.adaptive, ctx.backend)
        return obs, {"n_steps": jnp.sum(sols.n_accepted),
                     "n_fevals": jnp.sum(sols.n_fevals),
                     "n_attempts": jnp.sum(sols.n_attempts)}, \
            jnp.all(sols.succeeded)

    # solve() takes the single-run combined hook above; this override
    # exists so the standalone stats hook ALSO describes the threaded
    # sequence this strategy's adaptive_saveat realizes (the base default
    # replays a restarting segmentation), keeping the hook family
    # self-consistent for subclassers and direct callers.
    def adaptive_saveat_stats(self, ctx, x0, t0, ts, params):
        return _threaded_saveat_stats(ctx, x0, t0, ts, params)

    # batched: the value drivers ARE the per-lane controllers — one run.
    def adaptive_batched(self, ctx, x0, t0, t1, params):
        sol = rk_solve_adaptive_batched(ctx.f, ctx.tab, x0, t0, t1, params,
                                        ctx.adaptive, ctx.backend)
        return apply_on_failure_lanes(sol.x_final, sol.succeeded,
                                      ctx.adaptive.on_failure)

    def adaptive_batched_with_stats(self, ctx, x0, t0, t1, params):
        sol = rk_solve_adaptive_batched(ctx.f, ctx.tab, x0, t0, t1, params,
                                        ctx.adaptive, ctx.backend)
        ys = apply_on_failure_lanes(sol.x_final, sol.succeeded,
                                    ctx.adaptive.on_failure)
        return ys, {"n_steps": sol.n_accepted, "n_fevals": sol.n_fevals,
                    "n_attempts": sol.n_attempts}, sol.succeeded

    def adaptive_saveat_batched(self, ctx, x0, t0, ts, params):
        obs, _ = rk_solve_adaptive_batched_saveat_stacked(
            ctx.f, ctx.tab, x0, t0, ts, params, ctx.adaptive, ctx.backend)
        return obs

    def adaptive_saveat_batched_with_stats(self, ctx, x0, t0, ts, params):
        obs, sols = rk_solve_adaptive_batched_saveat_stacked(
            ctx.f, ctx.tab, x0, t0, ts, params, ctx.adaptive, ctx.backend)
        return obs, {"n_steps": jnp.sum(sols.n_accepted, axis=0),
                     "n_fevals": jnp.sum(sols.n_fevals, axis=0),
                     "n_attempts": jnp.sum(sols.n_attempts, axis=0)}, \
            jnp.all(sols.succeeded, axis=0)

    def adaptive_saveat_batched_stats(self, ctx, x0, t0, ts, params):
        return _threaded_saveat_batched_stats(ctx, x0, t0, ts, params)

    def dense_saveat_with_stats(self, ctx, x0, t0, ts, params):
        # ONE unsegmented solve + Hermite interpolation: value and stats
        # come from the same controller run (2 extra f-evals per
        # observation for the endpoint slopes).
        cfg = ctx.adaptive
        sol = rk_solve_adaptive(ctx.f, ctx.tab, x0, t0, ts[-1], params,
                                cfg, ctx.backend)
        obs = hermite_observe(ctx.f, ctx.tab, sol, params, ts, ctx.backend)
        ys = apply_on_failure(obs, sol.succeeded, cfg.on_failure)
        stats = {"n_steps": sol.n_accepted,
                 "n_fevals": sol.n_fevals + 2 * ts.shape[0],
                 "n_attempts": sol.n_attempts}
        return ys, stats, sol.succeeded


@register_gradient
@dataclasses.dataclass(frozen=True)
class RematStep(GradientStrategy):
    """ANODE/ACA-style per-step rematerialization (exact; O(N + s L))."""
    name: ClassVar[str] = "remat_step"
    capabilities: ClassVar[FrozenSet] = frozenset({_FIXED_T1, _FIXED_TS})

    def fixed(self, ctx, x0, t0, t1, params):
        return odeint_remat_step(ctx.f, ctx.tab, ctx.n_steps, x0, t0, t1,
                                 params, ctx.backend)


@register_gradient
@dataclasses.dataclass(frozen=True)
class RematSolve(GradientStrategy):
    """Whole-solve rematerialization, the paper's baseline scheme (exact;
    O(M) forward, O(N s L) inside the backward)."""
    name: ClassVar[str] = "remat_solve"
    capabilities: ClassVar[FrozenSet] = frozenset({_FIXED_T1, _FIXED_TS})

    def fixed(self, ctx, x0, t0, t1, params):
        return odeint_remat_solve(ctx.f, ctx.tab, ctx.n_steps, x0, t0, t1,
                                  params, ctx.backend)


@register_gradient
@dataclasses.dataclass(frozen=True)
class ContinuousAdjoint(GradientStrategy):
    """Chen et al. 2018 continuous adjoint: O(L) memory, approximate
    gradient (O(h^p) backward-integration error).

    steps_multiplier — fixed-grid backward solves take
                       ``n_steps * steps_multiplier`` steps (must be >= 1:
                       a zero-step backward solve silently returns garbage
                       gradients).
    bwd_adaptive     — controller for the adaptive backward solve of the
                       augmented system (defaults to the forward config).
    """
    name: ClassVar[str] = "adjoint"
    capabilities: ClassVar[FrozenSet] = frozenset(
        {_FIXED_T1, _FIXED_TS, _ADAPT_T1, _ADAPT_TS})
    batched_capabilities: ClassVar[FrozenSet] = frozenset(
        {_ADAPT_T1, _ADAPT_TS})

    steps_multiplier: int = 1
    bwd_adaptive: Optional[AdaptiveConfig] = None

    def __post_init__(self):
        if not isinstance(self.steps_multiplier, (int, np.integer)) \
                or isinstance(self.steps_multiplier, bool) \
                or self.steps_multiplier < 1:
            raise ValueError(
                "ContinuousAdjoint.steps_multiplier must be an int >= 1 "
                "(a zero-step backward solve returns garbage gradients); "
                f"got {self.steps_multiplier!r}")
        # normalize so the custom_vjp nondiff-arg hashing sees a plain int
        object.__setattr__(self, "steps_multiplier",
                           int(self.steps_multiplier))

    def fixed(self, ctx, x0, t0, t1, params):
        return odeint_adjoint(ctx.f, ctx.tab, ctx.n_steps,
                              self.steps_multiplier, ctx.backend,
                              x0, t0, t1, params)

    def adaptive(self, ctx, x0, t0, t1, params):
        return odeint_adjoint_adaptive(
            ctx.f, ctx.tab, ctx.adaptive,
            self.bwd_adaptive or ctx.adaptive, ctx.backend,
            x0, t0, t1, params)

    def adaptive_batched(self, ctx, x0, t0, t1, params):
        # per-lane forward AND backward grids; the backward augmented state
        # carries a per-lane grad-theta accumulator — O(B L) memory
        # (core/adjoint.py, docs/batching.md).
        return odeint_adjoint_adaptive_batched(
            ctx.f, ctx.tab, ctx.adaptive,
            self.bwd_adaptive or ctx.adaptive, ctx.backend,
            x0, t0, t1, params)
    # SaveAt value AND stats both come from the base class (batched and
    # not): generic restart-per-segment segmentation + the matching
    # restart replay.


# ---------------------------------------------------------------------------
# Capability matrix
# ---------------------------------------------------------------------------

def capability_matrix() -> Dict[str, Dict[Tuple[str, str], bool]]:
    """The full declarative (gradient x stepping x saveat) legality table,
    assembled from the registered strategies (docs/api.md renders it via
    tools/gen_capability_table.py)."""
    return {name: {(sk, vk): (sk, vk) in cls.capabilities
                   for sk in STEPPING_KINDS for vk in SAVEAT_KINDS}
            for name, cls in sorted(GRADIENT_REGISTRY.items())}


def batched_capability_matrix() -> Dict[str, Dict[Tuple[str, str], bool]]:
    """Same table for ``solve(..., batch_axis=0)``: which cells each
    strategy supports with masked per-lane step control (every fixed cell a
    strategy claims, plus its declared batched adaptive cells)."""
    return {name: {(sk, vk): (sk, vk) in cls.batched_cells()
                   for sk in STEPPING_KINDS for vk in SAVEAT_KINDS}
            for name, cls in sorted(GRADIENT_REGISTRY.items())}


def mesh_capability_matrix() -> Dict[str, Dict[Tuple[str, str], bool]]:
    """Same table for ``solve(..., batch_axis=0, mesh=...)``: the batched
    cells restricted to t1|ts saveat.  The mesh path shard_maps the SAME
    batched hooks (``fixed``/``fixed_saveat``/``adaptive_*_with_stats``),
    so every batched t1/ts cell is mesh-legal; dense output is not wired
    through shard_map."""
    return {name: {cell: ok and cell[1] in ("t1", "ts")
                   for cell, ok in cells.items()}
            for name, cells in batched_capability_matrix().items()}


def _check_capability(gradient: GradientStrategy, stepping_kind: str,
                      saveat_kind: str, batched: bool = False) -> None:
    cells = (type(gradient).batched_cells() if batched
             else type(gradient).capabilities)
    if (stepping_kind, saveat_kind) in cells:
        return
    name = type(gradient).name
    legal = ", ".join(f"{sk}+{vk}" for sk, vk in sorted(cells))
    ctx = " with batch_axis=0" if batched else ""
    raise ValueError(
        f"gradient {name!r} does not support stepping={stepping_kind!r} "
        f"with saveat={saveat_kind!r}{ctx}; legal (stepping+saveat) "
        f"combinations for {name!r}{ctx}: {legal}.  See the capability "
        "matrix in docs/api.md")


# ---------------------------------------------------------------------------
# solve
# ---------------------------------------------------------------------------

def _fixed_stats(tab: ButcherTableau, n_steps: int, n_segments: int,
                 lanes: Optional[int] = None):
    """Fixed-grid stats are exact static counts: the drivers skip the
    embedded error estimate, so the cost is exactly s f-evals per step.
    With ``lanes`` (batch_axis=0) the counts broadcast per lane — every
    lane takes the same deterministic grid."""
    total = n_segments * n_steps
    fevals = total * tab.s
    if lanes is None:
        return ({"n_steps": jnp.int32(total),
                 "n_fevals": jnp.int32(fevals),
                 "n_attempts": jnp.int32(total)}, jnp.asarray(True))
    return ({"n_steps": jnp.full((lanes,), total, jnp.int32),
             "n_fevals": jnp.full((lanes,), fevals, jnp.int32),
             "n_attempts": jnp.full((lanes,), total, jnp.int32)},
            jnp.ones((lanes,), bool))


def solve(f: VectorField, x0, params, *,
          saveat: Optional[SaveAt] = None,
          method: Union[str, ButcherTableau] = "dopri5",
          gradient: Union[str, GradientStrategy, None] = None,
          stepping: Union[int, AdaptiveConfig] = 16,
          backend: str = "auto",
          t0=0.0,
          batch_axis: Optional[int] = None,
          mesh=None,
          sharding=None) -> Solution:
    """Integrate ``dx/dt = f(x, t, params)`` and return a ``Solution``.

    f          — vector field over arbitrary pytrees; times are not
                 differentiated (zero cotangents), matching the paper's
                 fixed-T setting.
    saveat     — observation scheme (default ``SaveAt(t1=1.0)``).
    method     — tableau name or a ``ButcherTableau``.
    gradient   — a ``GradientStrategy`` (or registered name; default
                 ``SymplecticAdjoint()``).
    stepping   — int N (fixed grid; N steps per observation segment) or an
                 ``AdaptiveConfig`` (``max_steps`` per segment).
    backend    — stage-combine dispatch: auto | jnp | pallas
                 (core/combine.py).
    t0         — start time (keyword; default 0).
    batch_axis — None (default): ONE trajectory; a leading batch axis in
                 the state is part of that single trajectory's state, so
                 an adaptive controller pools its error norm over the
                 whole batch (lockstep).  0: the leading axis of every
                 state leaf indexes B INDEPENDENT trajectories — adaptive
                 solves run masked per-lane step control (each lane its
                 own accepted grid, error norm, and accept/reject; exact
                 per-lane gradients under the symplectic adjoint), and
                 ``stats``/``success`` become per-lane (B,) arrays.  Times
                 (``t0``, ``saveat``) stay shared.  Only axis 0 is
                 supported.  See docs/batching.md.
    mesh       — a ``jax.sharding.Mesh``: shard the lane axis over the
                 mesh's data axes (the longest divisible prefix of
                 ``("pod", "data")``) with ``shard_map``.  Requires
                 ``batch_axis=0`` and saveat t1|ts.  Per-lane controller
                 state stays shard-local; both exact backward passes
                 replay shard-locally with the param-cotangent psum as
                 the only real collective, and ``stats`` gains
                 ``shard_steps`` / ``load_imbalance``.  See
                 docs/parallel.md.
    sharding   — params placement under ``mesh``: None (replicated,
                 default), ``"auto"`` (``repro.parallel`` path rules), or
                 an explicit ``PartitionSpec`` pytree/prefix.
    """
    tab = get_tableau(method) if isinstance(method, str) else method
    resolve_backend(backend)  # eager validation, single source
    gradient = as_gradient("symplectic" if gradient is None else gradient)
    saveat = SaveAt(t1=1.0) if saveat is None else saveat
    if batch_axis is not None and batch_axis != 0:
        raise ValueError(
            f"batch_axis={batch_axis!r}: only the leading axis "
            "(batch_axis=0) is supported — move the trajectory axis of "
            "every state leaf to axis 0")
    batched = batch_axis is not None
    lanes = lane_count(x0) if batched else None

    if isinstance(stepping, AdaptiveConfig):
        stepping_kind, n_steps, adaptive = "adaptive", None, stepping
    elif isinstance(stepping, (int, np.integer)) \
            and not isinstance(stepping, bool):
        if stepping < 1:
            raise ValueError(
                f"stepping={stepping}: a fixed-grid solve needs >= 1 steps")
        stepping_kind, n_steps, adaptive = "fixed", int(stepping), None
    else:
        raise TypeError(
            "stepping must be an int (fixed-grid step count) or an "
            f"AdaptiveConfig; got {type(stepping).__name__}")

    _check_capability(gradient, stepping_kind, saveat.kind, batched)
    t0 = jnp.asarray(t0, dtype=jnp.result_type(float))
    ctx = _Ctx(f, tab, n_steps, adaptive, backend)

    if mesh is None and sharding is not None:
        raise ValueError("solve(sharding=...) requires mesh=: the params "
                         "placement only means something on a mesh")
    if mesh is not None:
        if not batched:
            raise ValueError(
                "solve(mesh=...) shards the lane axis over the mesh's data "
                "axes: pass batch_axis=0 (a single trajectory has no lane "
                "axis to shard — see docs/parallel.md)")
        return _solve_sharded(gradient, ctx, tab, n_steps, stepping_kind,
                              saveat, x0, t0, params, lanes, mesh, sharding)

    if saveat.kind == "t1":
        t1 = jnp.asarray(saveat.t1, dtype=t0.dtype)
        if stepping_kind == "fixed":
            # the fixed grid is state-independent: the plain driver IS the
            # per-lane solve, only the stats shapes change.
            ys = gradient.fixed(ctx, x0, t0, t1, params)
            stats, success = _fixed_stats(tab, n_steps, 1, lanes)
        elif batched:
            ys, stats, success = gradient.adaptive_batched_with_stats(
                ctx, x0, t0, t1, params)
        else:
            ys, stats, success = gradient.adaptive_with_stats(
                ctx, x0, t0, t1, params)
        return Solution(ys=ys, final_state=ys, stats=stats, success=success)

    ts = _as_ts(saveat.ts, t0.dtype, t0)
    if saveat.kind == "ts":
        if stepping_kind == "fixed":
            ys = gradient.fixed_saveat(ctx, x0, t0, ts, params)
            stats, success = _fixed_stats(tab, n_steps, ts.shape[0], lanes)
        elif batched:
            ys, stats, success = gradient.adaptive_saveat_batched_with_stats(
                ctx, x0, t0, ts, params)
        else:
            ys, stats, success = gradient.adaptive_saveat_with_stats(
                ctx, x0, t0, ts, params)
    else:  # dense
        ys, stats, success = gradient.dense_saveat_with_stats(
            ctx, x0, t0, ts, params)

    final = jax.tree_util.tree_map(lambda l: l[-1], ys)
    return Solution(ys=ys, final_state=final, stats=stats, success=success)


def _solve_sharded(gradient: GradientStrategy, ctx: _Ctx,
                   tab: ButcherTableau, n_steps: Optional[int],
                   stepping_kind: str, saveat: SaveAt, x0, t0, params,
                   lanes: int, mesh, sharding) -> Solution:
    """The mesh path of ``solve``: run the SAME dispatch as the unsharded
    batched solve, but as a shard-local body under ``shard_map`` — each
    shard solves its contiguous lane block exactly as a single-device call
    would (bitwise: values, per-lane stats, grids, h carries).  Lives here
    rather than in ``repro.parallel`` so the dispatch stays next to the
    unsharded branch it must mirror; the mesh mechanics (lane-axis
    selection, specs, load stats) come from ``repro.parallel.solve``.
    """
    from ..parallel import solve as _pps  # parallel imports core: lazy
    axes = _pps.lane_axes(mesh, lanes, require=True)
    n_shards = _pps.shard_count(mesh, axes)
    lanes_local = lanes // n_shards
    # rank-0 param leaves stay lifted to (1,) through the whole shard-local
    # driver (they are saved as custom_vjp residuals, and jax 0.4.37's
    # shard_map transpose cannot handle rank-0 residuals/inputs); only the
    # user field sees the original scalars.
    params, _restore, _lifted = _pps.lift_scalar_params(params)
    if _lifted:
        _f = ctx.f
        ctx = _Ctx(lambda x, t, p: _f(x, t, _restore(p)), ctx.tab,
                   ctx.n_steps, ctx.adaptive, ctx.backend)
    pspec = _pps.resolve_param_specs(params, mesh, sharding)

    if saveat.kind == "t1":
        t1 = jnp.asarray(saveat.t1, dtype=t0.dtype)
        if stepping_kind == "fixed":
            def body(x0_, params_):
                ys = gradient.fixed(ctx, x0_, t0, t1, params_)
                stats, success = _fixed_stats(tab, n_steps, 1, lanes_local)
                return ys, stats, success
        else:
            def body(x0_, params_):
                return gradient.adaptive_batched_with_stats(
                    ctx, x0_, t0, t1, params_)
        ys, stats, success = _pps.sharded_solve_triple(
            body, mesh, axes, x0, params, params_spec=pspec, ys_lane_axis=0)
        stats = _pps.with_shard_load_stats(stats, n_shards)
        return Solution(ys=ys, final_state=ys, stats=stats, success=success)

    if saveat.kind != "ts":
        # unreachable today (_check_capability rejects batched dense), but
        # the mesh path must never silently fall through to a new kind.
        raise ValueError(
            f"solve(mesh=...) supports saveat t1|ts; got {saveat.kind!r}")
    ts = _as_ts(saveat.ts, t0.dtype, t0)
    if stepping_kind == "fixed":
        def body(x0_, params_):
            ys = gradient.fixed_saveat(ctx, x0_, t0, ts, params_)
            stats, success = _fixed_stats(tab, n_steps, ts.shape[0],
                                          lanes_local)
            return ys, stats, success
    else:
        def body(x0_, params_):
            return gradient.adaptive_saveat_batched_with_stats(
                ctx, x0_, t0, ts, params_)
    # SaveAt stacks are time-major: lanes live on axis 1 of the ys leaves.
    ys, stats, success = _pps.sharded_solve_triple(
        body, mesh, axes, x0, params, params_spec=pspec, ys_lane_axis=1)
    stats = _pps.with_shard_load_stats(stats, n_shards)
    final = jax.tree_util.tree_map(lambda l: l[-1], ys)
    return Solution(ys=ys, final_state=final, stats=stats, success=success)
