"""Probe-case enumeration: every legal solve configuration, as jaxprs.

The case list is ENUMERATED from ``GRADIENT_REGISTRY`` — the same
declarative ``capabilities`` / ``batched_cells()`` frozensets ``solve``
enforces — so a newly registered strategy (or a capability change) is
analyzed automatically, exactly like the docs capability tables.

Each case closes a small MLP-field solve into jaxprs under x64 with f64
inputs (so any hardcoded narrower dtype surfaces as a
``convert_element_type`` demotion): a ``value`` jaxpr always, and a
``grad`` jaxpr where the cell is reverse-differentiable — every fixed
cell, and the adaptive cells of the custom-VJP strategies (symplectic,
adjoint).  DirectBackprop's adaptive cells are value/JVP-only (reverse
cannot cross ``lax.while_loop``) and dense output is value-only, matching
docs/gradients.md.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import AdaptiveConfig, SaveAt, solve
from repro.core.api import GRADIENT_REGISTRY

__all__ = ["Case", "enumerate_cases", "case_jaxprs", "mlp_field",
           "make_probe", "ensure_x64", "CUSTOM_VJP_STRATEGIES",
           "engine_advance_probe", "sharded_solve_probe",
           "SHARDED_PROBE_CELLS"]

# strategies whose adaptive drivers are custom_vjp (reverse-differentiable
# across the while_loop); everything else is fixed-grid-grad only
CUSTOM_VJP_STRATEGIES = frozenset({"symplectic", "adjoint"})


def ensure_x64() -> None:
    """The dtype rule probes with f64 inputs: without x64 they silently
    become f32 and every demotion disappears.  Idempotent."""
    jax.config.update("jax_enable_x64", True)


@dataclasses.dataclass(frozen=True)
class Case:
    """One (strategy, stepping, saveat, batched, method) probe cell."""
    strategy: str
    stepping: str                 # "fixed" | "adaptive"
    saveat: str                   # "t1" | "ts" | "dense"
    batched: bool
    method: str = "dopri5"

    @property
    def key(self) -> str:
        mode = "batched" if self.batched else "single"
        return "/".join([self.strategy, self.method, self.stepping,
                         self.saveat, mode])

    @property
    def differentiable(self) -> bool:
        """Reverse-mode legal for this cell (docs/gradients.md)."""
        if self.saveat == "dense":
            return False
        return self.stepping == "fixed" \
            or self.strategy in CUSTOM_VJP_STRATEGIES


def enumerate_cases(methods: Tuple[str, ...] = ("dopri5",)):
    """Every legal cell of every registered strategy, single and batched."""
    cases = []
    for name in sorted(GRADIENT_REGISTRY):
        cls = GRADIENT_REGISTRY[name]
        for method in methods:
            for sk, vk in sorted(cls.capabilities):
                cases.append(Case(name, sk, vk, False, method))
            for sk, vk in sorted(cls.batched_cells()):
                cases.append(Case(name, sk, vk, True, method))
    return cases


def mlp_field(x_is_batched: bool = False):
    """Tiny tanh-MLP vector field f(x, t, params); works for (dim,) and
    (B, dim) states (the ops are dim-generic)."""
    del x_is_batched

    def field(x, t, params):
        h = jnp.tanh(x @ params["w1"] + params["b1"] + t * params["bt"])
        return h @ params["w2"] + params["b2"]
    return field


def make_probe(case: Case, *, dim: int = 4, hidden: int = 16,
               batch: int = 3, n_steps: int = 3, max_steps: int = 8,
               n_obs: int = 4, dtype=jnp.float64):
    """(value_fn, grad_fn_or_None, example_args) for one case.

    Only avals matter for the analysis, so inputs are zeros; nothing is
    ever executed — the probes exist to be ``jax.make_jaxpr``'d.
    """
    ensure_x64()
    field = mlp_field(case.batched)
    x0 = jnp.zeros((batch, dim) if case.batched else (dim,), dtype)
    params = {"w1": jnp.zeros((dim, hidden), dtype),
              "b1": jnp.zeros((hidden,), dtype),
              "bt": jnp.zeros((hidden,), dtype),
              "w2": jnp.zeros((hidden, dim), dtype),
              "b2": jnp.zeros((dim,), dtype)}
    stepping = n_steps if case.stepping == "fixed" else \
        AdaptiveConfig(max_steps=max_steps)
    if case.saveat == "t1":
        saveat = SaveAt(t1=1.0)
    else:
        saveat = SaveAt(ts=jnp.linspace(1.0 / n_obs, 1.0, n_obs,
                                        dtype=dtype),
                        dense=case.saveat == "dense")
    batch_axis = 0 if case.batched else None

    def value_fn(x0, params):
        sol = solve(field, x0, params, saveat=saveat, method=case.method,
                    gradient=case.strategy, stepping=stepping,
                    backend="jnp", batch_axis=batch_axis)
        return sol.ys

    grad_fn = None
    if case.differentiable:
        def loss_fn(x0, params):
            ys = value_fn(x0, params)
            return sum(jnp.sum(jnp.sin(leaf) ** 2)
                       for leaf in jax.tree_util.tree_leaves(ys))
        grad_fn = jax.grad(loss_fn, argnums=(0, 1))
    return value_fn, grad_fn, (x0, params)


def engine_advance_probe(method: str = "dopri5", *, dim: int = 32,
                         hidden: int = 16, lanes: int = 8,
                         max_steps: int = 64, dtype=jnp.float64):
    """The serve engine's hot entry point, as a (jaxpr, donated-set) pair.

    Traces ``AdaptiveStepper.advance`` over a lane-batched ``SolverState``
    with tolerances-as-data — exactly the shape the continuous-batching
    engine AOT-compiles with ``donate_argnums=0`` — sized so the slot
    checkpoint buffers clear the donation rule's ``min_bytes`` floor.
    ``donated`` is the flat invar index set of the state leaves (argument
    0), letting the donation-hazard rule verify at ERROR severity that
    every large state output aliases a donated input: the engine's
    in-place slot-update contract (docs/serving.md).
    """
    ensure_x64()
    from repro.core.stepper import AdaptiveStepper
    from repro.core.tableau import get_tableau
    field = mlp_field()
    params = {"w1": jnp.zeros((dim, hidden), dtype),
              "b1": jnp.zeros((hidden,), dtype),
              "bt": jnp.zeros((hidden,), dtype),
              "w2": jnp.zeros((hidden, dim), dtype),
              "b2": jnp.zeros((dim,), dtype)}
    cfg = AdaptiveConfig(max_steps=max_steps)
    stepper = AdaptiveStepper(field, get_tableau(method), cfg,
                              combine_backend="jnp")
    x0 = jnp.zeros((lanes, dim), dtype)
    state = stepper.init_state(x0, 0.0, 1.0, lanes=lanes,
                               rtol=cfg.rtol, atol=cfg.atol)
    closed = jax.make_jaxpr(stepper.advance)(state, params)
    donated = frozenset(range(len(jax.tree_util.tree_leaves(state))))
    return closed, donated


# (strategy, stepping) cells audited by the collective-count rule — the
# custom-VJP strategies' mesh-reachable t1 cells (fixed once: the fixed
# grid is strategy-independent at the shard_map boundary)
SHARDED_PROBE_CELLS = (("symplectic", "adaptive"), ("adjoint", "adaptive"),
                       ("symplectic", "fixed"))


def sharded_solve_probe(strategy: str, stepping_kind: str,
                        method: str = "dopri5", *, dim: int = 4,
                        hidden: int = 16, batch: int = 3, n_steps: int = 3,
                        max_steps: int = 8, dtype=jnp.float64):
    """One ``solve(mesh=...)`` cell as jaxprs for the collective-count rule.

    Traces on a (1,)-device ``("data",)`` mesh: shard_map emits the SAME
    jaxpr structure (body nesting, transpose-inserted psums) for a 1-way
    mesh as for an N-way one, so the communication contract is auditable
    in a single-device CI lane.  Returns
    ``{"value": ClosedJaxpr, "grad": ClosedJaxpr, "param_shapes": [...]}``
    — the shapes feed the rule's one-psum-per-theta-leaf check.
    """
    ensure_x64()
    from repro.launch.mesh import make_lane_mesh
    mesh = make_lane_mesh((1,))
    field = mlp_field(True)
    x0 = jnp.zeros((batch, dim), dtype)
    params = {"w1": jnp.zeros((dim, hidden), dtype),
              "b1": jnp.zeros((hidden,), dtype),
              "bt": jnp.zeros((hidden,), dtype),
              "w2": jnp.zeros((hidden, dim), dtype),
              "b2": jnp.zeros((dim,), dtype)}
    stepping = n_steps if stepping_kind == "fixed" else \
        AdaptiveConfig(max_steps=max_steps)

    def value_fn(x0, params):
        sol = solve(field, x0, params, method=method, gradient=strategy,
                    stepping=stepping, backend="jnp", batch_axis=0,
                    mesh=mesh)
        return sol.ys

    def loss_fn(x0, params):
        return jnp.sum(jnp.sin(value_fn(x0, params)) ** 2)

    return {"value": jax.make_jaxpr(value_fn)(x0, params),
            "grad": jax.make_jaxpr(jax.grad(loss_fn,
                                            argnums=(0, 1)))(x0, params),
            "param_shapes": [jnp.shape(p) for p in
                             jax.tree_util.tree_leaves(params)]}


def case_jaxprs(case: Case, **knobs) -> Dict[str, Optional[object]]:
    """Trace one case: {"value": ClosedJaxpr, "grad": ClosedJaxpr | None}."""
    value_fn, grad_fn, args = make_probe(case, **knobs)
    out = {"value": jax.make_jaxpr(value_fn)(*args), "grad": None}
    if grad_fn is not None:
        out["grad"] = jax.make_jaxpr(grad_fn)(*args)
    return out
