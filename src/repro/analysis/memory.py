"""Memory-bound rule: the paper's Table-1 ordering, checked statically.

For each registered strategy the rule traces the reverse-mode jaxpr of a
fixed-grid solve (small state, wide hidden layer — so the network term L
dominates the per-step checkpoints) at N and 8N steps, runs the liveness
accounting of ``traversal.peak_resident_bytes`` on each, and asserts the
scaling the paper proves:

  symplectic   peak O(N + s + L): FLAT in N within a small constant — the
               N-dependence is only the (N, state)-shaped checkpoint
               buffer, negligible against the one live stage-VJP graph.
  remat_step   peak O(N + s L): flat for the same reason (carries
               checkpointed, one step's graph rematerialized at a time).
  adjoint      peak O(L): flat (one augmented backward solve, no stacked
               residuals; approximate gradient).
  backprop     peak O(N s L): ~LINEAR in N — the forward scan stacks every
               stage's activations as reverse-mode residuals.
  remat_solve  O(N) forward but O(N s L) inside the backward remat region:
               ~linear, the paper's baseline scheme.

The growth-factor thresholds are deliberately loose (flat <= FLAT_MAX,
linear >= LINEAR_MIN at an 8x step growth) so the check pins the
*asymptotics*, not jax-version-dependent byte constants.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.api import GRADIENT_REGISTRY

from .cases import ensure_x64, mlp_field
from .rules import ERROR, Finding
from .traversal import dce, peak_resident_bytes

__all__ = ["MemoryRow", "memory_rows", "memory_findings",
           "memory_table_markdown", "MEMORY_METHODS", "N_SMALL", "N_BIG",
           "FLAT_MAX", "LINEAR_MIN", "PAPER_BOUNDS", "FLAT_STRATEGIES",
           "LINEAR_STRATEGIES"]

MEMORY_METHODS: Tuple[str, ...] = ("dopri5", "bosh3")
N_SMALL, N_BIG = 8, 64            # the acceptance criterion's 8x growth
DIM, HIDDEN = 4, 256              # small state, wide net: L >> N * state

FLAT_MAX = 1.5                    # "flat within a small constant"
LINEAR_MIN = 3.0                  # "~linear" at 8x steps (loose on purpose)
LINEAR_MIN_S1 = 2.0               # single-stage methods (euler): the fixed
#                                   graph term L dilutes the N-slope, so the
#                                   linear floor is lower but still > FLAT_MAX
ORDER_MARGIN = 2.0                # symplectic must beat backprop by >= 2x

FLAT_STRATEGIES = ("symplectic", "remat_step", "adjoint")
LINEAR_STRATEGIES = ("backprop", "remat_solve")

# the repo's Table-1 mapping (docs/gradients.md notation: N steps, s
# stages, L network-evaluation graph)
PAPER_BOUNDS: Dict[str, str] = {
    "symplectic": "O(N + s + L) — Table 1, proposed method",
    "backprop": "O(N s L) — Table 1, naive backprop",
    "remat_step": "O(N + s L) — ACA/ANODE per-step remat",
    "remat_solve": "O(N) fwd / O(N s L) bwd — Table 1 baseline scheme",
    "adjoint": "O(L) — Table 1 adjoint (approximate gradient)",
}


@dataclasses.dataclass(frozen=True)
class MemoryRow:
    strategy: str
    method: str
    n_small: int
    peak_small: int
    n_big: int
    peak_big: int

    @property
    def growth(self) -> float:
        return self.peak_big / max(self.peak_small, 1)


def _grad_peak_bytes(strategy: str, method: str, n_steps: int,
                     dim: int = DIM, hidden: int = HIDDEN) -> int:
    """Peak resident bytes of the reverse-mode jaxpr of one fixed-grid
    t1 solve (every strategy supports this cell, and fixed-grid reverse
    mode is legal for all five)."""
    ensure_x64()
    field = mlp_field()
    x0 = jnp.zeros((dim,), jnp.float64)
    params = {"w1": jnp.zeros((dim, hidden), jnp.float64),
              "b1": jnp.zeros((hidden,), jnp.float64),
              "bt": jnp.zeros((hidden,), jnp.float64),
              "w2": jnp.zeros((hidden, dim), jnp.float64),
              "b2": jnp.zeros((dim,), jnp.float64)}

    from repro.core import solve

    def loss(x0, params):
        sol = solve(field, x0, params, method=method, gradient=strategy,
                    stepping=n_steps, backend="jnp")
        return jnp.sum(jnp.sin(sol.ys) ** 2)

    closed = jax.make_jaxpr(jax.grad(loss, argnums=(0, 1)))(x0, params)
    return peak_resident_bytes(dce(closed.jaxpr))


def memory_rows(methods: Tuple[str, ...] = MEMORY_METHODS,
                n_small: int = N_SMALL,
                n_big: int = N_BIG) -> List[MemoryRow]:
    rows = []
    for method in methods:
        for name in sorted(GRADIENT_REGISTRY):
            rows.append(MemoryRow(
                name, method, n_small,
                _grad_peak_bytes(name, method, n_small),
                n_big, _grad_peak_bytes(name, method, n_big)))
    return rows


def memory_findings(rows: List[MemoryRow]) -> List[Finding]:
    """The machine-checked Table-1 ordering."""
    out = []
    by = {(r.strategy, r.method): r for r in rows}
    methods = sorted({r.method for r in rows})
    for method in methods:
        for name in FLAT_STRATEGIES:
            r = by.get((name, method))
            if r and r.growth > FLAT_MAX:
                out.append(Finding(
                    "memory-bound", ERROR, f"{name}/{method}",
                    f"peak grew {r.growth:.2f}x at {r.n_big // r.n_small}x "
                    f"steps ({r.peak_small} -> {r.peak_big} B) but "
                    f"{PAPER_BOUNDS[name]} requires flat (<= {FLAT_MAX}x)"))
        from repro.core.tableau import get_tableau
        linear_min = LINEAR_MIN if len(get_tableau(method).b) >= 3 \
            else LINEAR_MIN_S1
        for name in LINEAR_STRATEGIES:
            r = by.get((name, method))
            if r and r.growth < linear_min:
                out.append(Finding(
                    "memory-bound", ERROR, f"{name}/{method}",
                    f"peak grew only {r.growth:.2f}x at "
                    f"{r.n_big // r.n_small}x steps ({r.peak_small} -> "
                    f"{r.peak_big} B): expected ~linear growth "
                    f"(>= {linear_min}x, {PAPER_BOUNDS[name]}) — the "
                    "residual accounting lost the stacked buffers"))
        sym = by.get(("symplectic", method))
        bp = by.get(("backprop", method))
        if sym and bp and sym.peak_big * ORDER_MARGIN > bp.peak_big:
            out.append(Finding(
                "memory-bound", ERROR, f"symplectic/{method}",
                f"Table-1 ordering violated at N={sym.n_big}: symplectic "
                f"peak {sym.peak_big} B is not <= backprop "
                f"{bp.peak_big} B / {ORDER_MARGIN}"))
    return out


def _fmt_bytes(b: int) -> str:
    if b >= 1 << 20:
        return f"{b / 2**20:.2f} MiB"
    return f"{b / 2**10:.1f} KiB"


def memory_table_markdown(rows: List[MemoryRow]) -> str:
    """The generated docs table (docs/analysis.md)."""
    lines = [
        "| strategy | method | peak @ N="
        f"{rows[0].n_small} | peak @ N={rows[0].n_big} | growth "
        "| paper bound |",
        "|----------|--------|------------|-------------|--------"
        "|-------------|",
    ]
    for r in rows:
        lines.append(
            f"| `{r.strategy}` | {r.method} | {_fmt_bytes(r.peak_small)} "
            f"| {_fmt_bytes(r.peak_big)} | {r.growth:.2f}x "
            f"| {PAPER_BOUNDS.get(r.strategy, '—')} |")
    return "\n".join(lines)
