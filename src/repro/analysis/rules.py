"""Pluggable analysis rules over closed jaxprs.

Every rule is a function ``(closed_jaxpr, case_key, **knobs) -> [Finding]``.
Findings carry a severity: ``error`` findings fail ``--check``; ``warning``
and ``info`` findings are reported but never gate CI.  The rule catalog is
documented in docs/analysis.md.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

import numpy as np

from .traversal import (aval_bytes, closed_constants, collective_eqns,
                        count_eqns, iter_eqns)

__all__ = ["Finding", "RULE_REGISTRY", "register_rule", "dtype_findings",
           "constant_findings", "donation_findings", "budget_findings",
           "flatness_findings", "collective_findings"]

ERROR, WARNING, INFO = "error", "warning", "info"


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    severity: str          # "error" | "warning" | "info"
    case: str              # enumerated-case key or synthetic jaxpr name
    message: str

    def __str__(self):
        return f"[{self.severity}] {self.rule} :: {self.case}: {self.message}"


RULE_REGISTRY: Dict[str, Callable] = {}


def register_rule(name: str):
    def deco(fn):
        RULE_REGISTRY[name] = fn
        return fn
    return deco


def _is_float(dt) -> bool:
    return np.issubdtype(np.dtype(dt), np.floating)


@register_rule("dtype-discipline")
def dtype_findings(closed, case: str = "<jaxpr>") -> List[Finding]:
    """Flag silent float precision changes (``convert_element_type``).

    Demotions (f64 -> f32, f32 -> bf16, ...) are ERRORS anywhere: traced
    with x64 inputs, a narrowing float convert means some intermediate
    hardcodes a dtype — the bug class hand-fixed in PRs 2-3 (cnf's f32
    time embedding, the f32 error norm).  Promotions inside scan/while
    bodies are WARNINGS (a widening cast per step is a bandwidth smell,
    e.g. an f32 accumulator repeatedly upcast to f64), except when the
    destination is exactly f32 — the deliberate >=f32 accumulate idiom for
    bf16/f16 states (kernels/ref.py).
    """
    out = []
    for eqn, ctx in iter_eqns(closed.jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        src = np.dtype(eqn.invars[0].aval.dtype)
        dst = np.dtype(eqn.params.get("new_dtype"))
        if not (_is_float(src) and _is_float(dst)):
            continue
        where = ("inside " + "/".join(ctx.path) if ctx.loop_depth
                 else "at the top level")
        if dst.itemsize < src.itemsize:
            out.append(Finding(
                "dtype-discipline", ERROR, case,
                f"float demotion {src} -> {dst} {where} "
                f"(loop depth {ctx.loop_depth}): an intermediate hardcodes "
                "a narrower dtype than the state"))
        elif dst.itemsize > src.itemsize and ctx.loop_depth > 0 \
                and dst != np.dtype(np.float32):
            out.append(Finding(
                "dtype-discipline", WARNING, case,
                f"float promotion {src} -> {dst} {where} "
                f"(loop depth {ctx.loop_depth}): widening cast repeats "
                "every iteration"))
    return out


@register_rule("constant-hazard")
def constant_findings(closed, case: str = "<jaxpr>",
                      min_bytes: int = 1 << 20) -> List[Finding]:
    """Large closed-over array constants (>= ``min_bytes``).

    A big constant baked into the jaxpr is recompile bait (a new trace per
    value) and ships a copy of the array inside every compiled executable;
    it should be an argument instead.  WARNING severity — the enumerated
    probe cases should never trip it, but model code swept through the
    analyzer legitimately closes over e.g. embedding tables.
    """
    out = []
    for shape, dtype, nbytes in closed_constants(closed):
        if nbytes >= min_bytes:
            out.append(Finding(
                "constant-hazard", WARNING, case,
                f"closed-over constant {dtype}{list(shape)} "
                f"({nbytes / 2**20:.1f} MiB >= {min_bytes / 2**20:.1f} MiB):"
                " pass it as an argument instead of baking it into the "
                "trace"))
    return out


@register_rule("donation-hazard")
def donation_findings(closed, case: str = "<jaxpr>",
                      min_bytes: int = 1 << 16,
                      donated=frozenset(),
                      severity: str = INFO) -> List[Finding]:
    """Undonated buffer opportunities on an entry point.

    An output whose (shape, dtype) matches an input of >= ``min_bytes``
    could reuse that input's buffer under ``jax.jit(...,
    donate_argnums=...)`` — the train-step / solver-state update pattern.
    INFO severity by default: a hint for the jit callsite, not a defect in
    the jaxpr.

    ``donated`` is the set of flat invar INDICES the callsite actually
    donates: each matching output first consumes a donated input of its
    aval (aliased — no finding), and only the remainder counts as missed
    opportunity.  Audited entry points that promise full donation (the
    serve engine's ``advance``, where every slot buffer must be reused in
    place) pass their donated set and ``severity="error"`` — any output
    left matching an UNdonated input then fails ``--check``.
    """
    out = []
    donated_avals, free_avals = {}, {}
    for i, v in enumerate(closed.jaxpr.invars):
        key = (tuple(getattr(v.aval, "shape", ())),
               str(getattr(v.aval, "dtype", "")))
        pool = donated_avals if i in donated else free_avals
        pool[key] = pool.get(key, 0) + 1
    matched = 0
    bytes_total = 0
    for v in closed.jaxpr.outvars:
        if hasattr(v, "val"):                       # literal output
            continue
        b = aval_bytes(v.aval)
        key = (tuple(getattr(v.aval, "shape", ())),
               str(getattr(v.aval, "dtype", "")))
        if b < min_bytes:
            continue
        if donated_avals.get(key, 0) > 0:           # aliased: already reused
            donated_avals[key] -= 1
            continue
        if free_avals.get(key, 0) > 0:
            free_avals[key] -= 1
            matched += 1
            bytes_total += b
    if matched:
        out.append(Finding(
            "donation-hazard", severity, case,
            f"{matched} output buffer(s) ({bytes_total / 2**10:.0f} KiB) "
            "match undonated input shapes/dtypes: donating the inputs "
            "(jit donate_argnums) would reuse their buffers"))
    return out


@register_rule("collective-count")
def collective_findings(closed, case: str = "<jaxpr>",
                        kind: str = "value",
                        param_shapes=None) -> List[Finding]:
    """The sharded solve's communication contract, proved jaxpr-level.

    Shard-local replay means the mesh path may communicate ONLY to reduce
    the replicated-param cotangents:

    * a ``value`` jaxpr must contain NO real collective (the forward and
      every per-lane controller decision are shard-local);
    * a ``grad`` jaxpr must contain EXACTLY one real ``psum`` per param
      leaf, each reducing an operand of that leaf's shape — and nothing
      else.  Any extra collective means lane state (grids, h carries,
      masks) started crossing devices: the exactness argument in
      docs/parallel.md is void.  Any missing psum means a param cotangent
      is silently shard-partial.

    ``psum`` markers with empty axes (shard_map transpose no-ops on
    lane-sharded cotangents) are ignored by ``collective_eqns``.
    """
    colls = collective_eqns(closed.jaxpr)
    out = []
    non_psum = [c for c in colls if c[0] != "psum"]
    if non_psum:
        out.append(Finding(
            "collective-count", ERROR, case,
            f"{kind} jaxpr contains non-psum collectives "
            f"{sorted({c[0] for c in non_psum})}: lane state is crossing "
            "devices (shard-local replay contract, docs/parallel.md)"))
    psum_shapes = sorted(shape for name, _, shapes in colls
                         if name == "psum" for shape in shapes)
    if kind == "value":
        if psum_shapes:
            out.append(Finding(
                "collective-count", ERROR, case,
                f"value jaxpr contains {len(psum_shapes)} real psum(s): "
                "the sharded forward must be collective-free"))
        return out
    expected = sorted(tuple(s) for s in (param_shapes or []))
    if psum_shapes != expected:
        out.append(Finding(
            "collective-count", ERROR, case,
            f"grad jaxpr psum operand shapes {psum_shapes} != one per "
            f"param leaf {expected}: the backward must all-reduce exactly "
            "the theta cotangents and nothing else"))
    return out


@register_rule("trace-size-budget")
def budget_findings(closed, case: str, budgets: Dict[str, int],
                    kind: str = "value") -> List[Finding]:
    """Ratchet total eqn count against ``analysis_budgets.json``.

    Over budget is an ERROR (a trace-size regression: some driver started
    unrolling).  A count under 80% of budget is INFO — re-run
    ``--write-budgets`` to tighten the ratchet after a deliberate
    improvement.  A case missing from the committed budgets is an ERROR in
    --check (new strategies must commit budgets with their PR).
    """
    key = f"{case}:{kind}"
    n = count_eqns(closed.jaxpr)
    budget = budgets.get(key)
    if budget is None:
        return [Finding(
            "trace-size-budget", ERROR, case,
            f"no committed budget for {key!r} (count {n}); run "
            "`python -m repro.analysis --write-budgets` and commit "
            "analysis_budgets.json")]
    if n > budget:
        return [Finding(
            "trace-size-budget", ERROR, case,
            f"{kind} jaxpr has {n} eqns > budget {budget}: trace-size "
            "regression (if intended, re-ratchet with --write-budgets)")]
    if n < 0.8 * budget:
        return [Finding(
            "trace-size-budget", INFO, case,
            f"{kind} jaxpr has {n} eqns, well under budget {budget}; "
            "consider tightening with --write-budgets")]
    return []


def flatness_findings(case: str, kind: str, n_small_obs: int, c_small: int,
                      n_big_obs: int, c_big: int,
                      tol: float = 1.10) -> List[Finding]:
    """O(1)-in-observations trace size for the SaveAt drivers: the eqn
    count at ``n_big_obs`` observation times must stay within ``tol`` of
    the count at ``n_small_obs`` (the scan-segmented drivers' contract,
    tests/test_trace_size.py)."""
    if c_big > tol * c_small:
        return [Finding(
            "trace-size-budget", ERROR, case,
            f"{kind} jaxpr grows with len(ts): {c_small} eqns at "
            f"{n_small_obs} observations -> {c_big} at {n_big_obs} "
            f"(> {tol:.2f}x): a SaveAt driver is unrolling over "
            "observations")]
    return []
