"""Jaxpr traversal: the engine every analysis rule walks on.

A traced solve is a nest of jaxprs: the top-level eqn list plus the
sub-jaxprs closed over by ``scan`` / ``while`` / ``cond`` / ``pjit`` /
``custom_vjp`` / ``remat`` eqn params.  Sub-jaxprs are discovered by DUCK
TYPING on the param values (an object with ``.jaxpr`` + ``.consts`` is a
ClosedJaxpr; one with ``.eqns`` + ``.invars`` is an open Jaxpr; lists and
tuples are searched elementwise) so the walker keeps working across jax
versions that move the concrete classes around.

Three accountings are built on the walk:

``count_eqns``          total eqn count across every nesting level — the
                        trace-size metric ``tests/test_trace_size.py`` pins
                        and ``analysis_budgets.json`` ratchets.
``iter_eqns``           flat iterator over (eqn, EqnContext) with the
                        loop-nesting depth and primitive path — what the
                        dtype-discipline rule needs to tell a hot-loop
                        demotion from a one-off cast.
``peak_resident_bytes`` define-to-last-use liveness over the eqn sequence:
                        the static analogue of peak HBM residency.  A
                        ``lax.scan``'s stacked outputs (DirectBackprop's
                        per-step residuals) surface as (N, ...)-shaped
                        outvars at the level ABOVE the loop body, so the
                        paper's Table-1 memory ordering is visible without
                        running anything.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

import numpy as np

__all__ = ["subjaxprs", "eqn_subjaxprs", "count_eqns", "iter_eqns",
           "EqnContext", "aval_bytes", "peak_resident_bytes", "dce",
           "closed_constants", "LOOP_PRIMITIVES", "COLLECTIVE_PRIMITIVES",
           "collective_eqns"]

# primitives whose sub-jaxprs execute once per iteration — eqns inside them
# are "hot" for the dtype rule (a demotion there repeats every step)
LOOP_PRIMITIVES = frozenset({"scan", "while"})

# cross-device communication primitives (what the collective-count rule
# audits inside shard_map bodies)
COLLECTIVE_PRIMITIVES = frozenset({
    "psum", "pmax", "pmin", "ppermute", "pshuffle", "all_gather",
    "all_to_all", "reduce_scatter", "pbroadcast"})


def subjaxprs(v) -> List:
    """Open jaxprs reachable from one eqn param value (duck-typed)."""
    if hasattr(v, "jaxpr") and hasattr(v, "consts"):    # ClosedJaxpr
        return [v.jaxpr]
    if hasattr(v, "eqns") and hasattr(v, "invars"):     # Jaxpr
        return [v]
    if isinstance(v, (list, tuple)):
        out = []
        for x in v:
            out.extend(subjaxprs(x))
        return out
    return []


def eqn_subjaxprs(eqn) -> List:
    """All sub-jaxprs an eqn closes over (scan/while bodies, cond branches,
    custom_vjp fwd/bwd, pjit callee, ...)."""
    out = []
    for v in eqn.params.values():
        out.extend(subjaxprs(v))
    return out


def count_eqns(jaxpr) -> int:
    """Total number of eqns including every nested sub-jaxpr."""
    n = len(jaxpr.eqns)
    for eqn in jaxpr.eqns:
        for sub in eqn_subjaxprs(eqn):
            n += count_eqns(sub)
    return n


@dataclasses.dataclass(frozen=True)
class EqnContext:
    """Where an eqn sits in the nest.

    loop_depth — number of enclosing scan/while bodies (> 0 means the eqn
                 re-executes every iteration: the hot path).
    path       — primitive names of the enclosing eqns, outermost first.
    """
    loop_depth: int = 0
    path: Tuple[str, ...] = ()


def iter_eqns(jaxpr, _depth: int = 0,
              _path: Tuple[str, ...] = ()) -> Iterator[Tuple[object,
                                                             EqnContext]]:
    """Yield (eqn, EqnContext) for every eqn at every nesting level."""
    ctx = EqnContext(loop_depth=_depth, path=_path)
    for eqn in jaxpr.eqns:
        yield eqn, ctx
        prim = eqn.primitive.name
        depth = _depth + (1 if prim in LOOP_PRIMITIVES else 0)
        for sub in eqn_subjaxprs(eqn):
            yield from iter_eqns(sub, depth, _path + (prim,))


def collective_eqns(jaxpr) -> List[Tuple[str, Tuple, Tuple]]:
    """Every REAL cross-device collective in the nest, as
    ``(primitive_name, axes, operand_shapes)`` tuples.

    ``psum`` eqns with empty ``axes`` are skipped: shard_map's transpose
    inserts them as structural no-op markers on cotangents of
    lane-sharded inputs — they lower to nothing and move no bytes.
    """
    out = []
    for eqn, _ in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name not in COLLECTIVE_PRIMITIVES:
            continue
        axes = tuple(eqn.params.get("axes", ()) or ())
        if name == "psum" and not axes:
            continue
        out.append((name, axes,
                    tuple(tuple(getattr(v.aval, "shape", ()))
                          for v in eqn.invars)))
    return out


def _is_var(atom) -> bool:
    """Var vs Literal, duck-typed (Literals carry ``.val``)."""
    return not hasattr(atom, "val")


def aval_bytes(aval) -> int:
    """Bytes of one abstract value; 0 for non-array avals."""
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    n = 1
    for d in shape:
        try:
            n *= int(d)
        except TypeError:           # symbolic / polymorphic dim
            return 0
    return n * np.dtype(dtype).itemsize


def _inner_extra_bytes(eqn) -> int:
    """Extra residency one execution of an eqn's sub-jaxprs adds on top of
    the caller's live set.  The sub-jaxpr's own inputs are (conservatively)
    treated as aliases of the caller's operand buffers already counted in
    the caller's live set, so only residency beyond the inputs counts.
    Alternative sub-jaxprs (cond branches, custom_vjp fwd/bwd) take the max
    — one of them runs at a time."""
    best = 0
    for sub in eqn_subjaxprs(eqn):
        inputs = sum(aval_bytes(v.aval)
                     for v in list(sub.invars) + list(sub.constvars))
        best = max(best, peak_resident_bytes(sub) - inputs)
    return max(best, 0)


def peak_resident_bytes(jaxpr) -> int:
    """Peak resident bytes of one execution under define-to-last-use
    liveness.

    Model: a var's buffer is live from the eqn that defines it (inputs and
    constvars from entry) to its last use (jaxpr outputs to exit); at each
    eqn the cost is the live set plus the extra internal residency of the
    eqn's sub-jaxprs (``_inner_extra_bytes`` — a scan body's cost recurs
    per iteration but never exceeds its single-iteration peak).  This is a
    fusion-free upper-bound shape of what XLA allocates; its value is the
    *scaling*, which is exact: stacked scan residuals appear as (N, ...)
    outvars, so O(N·s·L) vs O(N + s + L) strategies separate statically.
    """
    eqns = list(jaxpr.eqns)
    n = len(eqns)
    boundary = list(jaxpr.invars) + list(jaxpr.constvars)
    if n == 0:
        return sum(aval_bytes(v.aval) for v in boundary)

    defs = {}                      # var -> defining position (-1 = input)
    last = {}                      # var -> last-use position (n = output)
    for v in boundary:
        defs[v] = -1
    for i, eqn in enumerate(eqns):
        for v in eqn.invars:
            if _is_var(v):
                last[v] = i
        for v in eqn.outvars:
            defs[v] = i
    for v in jaxpr.outvars:
        if _is_var(v):
            last[v] = n

    alloc = [0] * n                # bytes becoming live at eqn i
    free = [0] * (n + 1)           # bytes dying after eqn i
    entry = 0
    for v, d in defs.items():
        b = aval_bytes(v.aval)
        if not b:
            continue
        if d < 0:
            entry += b
            # unused inputs still occupy their buffers for the whole call
            end = last.get(v, n)
        else:
            alloc[d] += b
            end = last.get(v, d)   # unused outputs die immediately
        if end < n:
            free[end] += b

    cur = entry
    peak = cur
    for i, eqn in enumerate(eqns):
        cur += alloc[i]
        peak = max(peak, cur + _inner_extra_bytes(eqn))
        cur -= free[i]
    return peak


def dce(jaxpr):
    """Best-effort dead-code elimination before liveness accounting.

    XLA is guaranteed to drop unused scan outputs (e.g. the checkpoint
    trajectory ``rk_solve_fixed`` stacks but a caller never reads), so a
    residency model that counts them reports phantom buffers — the
    continuous adjoint's backward solve would look O(N·L) instead of O(L).
    Falls back to the raw jaxpr if the partial_eval API moves.
    """
    try:
        from jax.interpreters.partial_eval import dce_jaxpr
    except Exception:                           # pragma: no cover
        return jaxpr
    pruned, _ = dce_jaxpr(jaxpr, [True] * len(jaxpr.outvars))
    return pruned


def closed_constants(closed) -> List[Tuple[Tuple[int, ...], str, int]]:
    """(shape, dtype, nbytes) of every array constant a ClosedJaxpr closes
    over, including nested ClosedJaxprs (scan bodies etc.)."""
    out = []
    seen = set()

    def visit_value(v):
        if hasattr(v, "jaxpr") and hasattr(v, "consts"):
            visit_closed(v)
        elif hasattr(v, "eqns") and hasattr(v, "invars"):
            visit_open(v)
        elif isinstance(v, (list, tuple)):
            for x in v:
                visit_value(x)

    def visit_closed(c):
        if id(c) in seen:
            return
        seen.add(id(c))
        for const in c.consts:
            if hasattr(const, "shape") and hasattr(const, "dtype"):
                out.append((tuple(const.shape), str(const.dtype),
                            int(np.prod(const.shape, dtype=np.int64))
                            * np.dtype(const.dtype).itemsize))
        visit_open(c.jaxpr)

    def visit_open(j):
        for eqn in j.eqns:
            for v in eqn.params.values():
                visit_value(v)

    visit_closed(closed)
    return out
