"""repro.analysis — jaxpr-level static auditor for the solver stack.

Proves, before anything runs, the properties the paper states statically:

  * the Table-1 memory ordering (symplectic O(N + s + L) flat in N vs
    DirectBackprop O(N s L) linear) via define-to-last-use liveness over
    each strategy's reverse-mode jaxpr          (``memory``)
  * dtype discipline: no silent float demotions in hot loops or cotangent
    paths                                        (``rules.dtype_findings``)
  * trace-size budgets: a committed eqn-count ratchet per enumerated
    solve case                                   (``rules.budget_findings``)
  * hazards: large closed-over constants, undonated entry-point buffers

Run it: ``PYTHONPATH=src python -m repro.analysis --check`` (the CI lane).
Docs: docs/analysis.md.
"""
from .cases import (Case, case_jaxprs, enumerate_cases, ensure_x64,
                    make_probe, mlp_field)
from .memory import (MemoryRow, memory_findings, memory_rows,
                     memory_table_markdown)
from .report import (AnalysisReport, BUDGET_PATH, load_budgets,
                     render_report, run_analysis, write_budgets)
from .rules import (Finding, RULE_REGISTRY, budget_findings,
                    constant_findings, donation_findings, dtype_findings,
                    flatness_findings, register_rule)
from .traversal import (EqnContext, aval_bytes, closed_constants,
                        count_eqns, dce, eqn_subjaxprs, iter_eqns,
                        peak_resident_bytes, subjaxprs)

__all__ = [
    "AnalysisReport", "BUDGET_PATH", "Case", "EqnContext", "Finding",
    "MemoryRow", "RULE_REGISTRY", "aval_bytes", "budget_findings",
    "case_jaxprs", "closed_constants", "constant_findings", "count_eqns",
    "dce", "donation_findings", "dtype_findings", "enumerate_cases",
    "ensure_x64",
    "eqn_subjaxprs", "flatness_findings", "iter_eqns", "load_budgets",
    "make_probe", "memory_findings", "memory_rows",
    "memory_table_markdown", "mlp_field", "peak_resident_bytes",
    "register_rule", "render_report", "run_analysis", "subjaxprs",
    "write_budgets",
]
