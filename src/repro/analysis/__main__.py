"""CLI: ``PYTHONPATH=src python -m repro.analysis [--check] ...``.

Modes
  (default)           trace every case, run every rule, print the report
  --check             same, exit 1 on any ERROR finding (the CI lane)
  --write-budgets     regenerate the analysis_budgets.json ratchet
  --write-docs-table  refresh the generated memory table in docs/analysis.md

Knobs
  --budgets PATH      ratchet file (default: <repo>/analysis_budgets.json)
  --methods a,b       tableaus for the per-case rules (default: dopri5;
                      the memory rule always runs dopri5 AND bosh3)
  --no-memory         skip the memory-bound rule (fast budget/dtype pass)
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from .report import (BUDGET_PATH, load_budgets, render_report, run_analysis,
                     write_budgets, write_docs_table)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr-level static auditor for the solver stack")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero on any error-severity finding")
    ap.add_argument("--write-budgets", action="store_true",
                    help="regenerate the trace-size budget ratchet")
    ap.add_argument("--write-docs-table", action="store_true",
                    help="refresh the generated table in docs/analysis.md")
    ap.add_argument("--budgets", type=pathlib.Path, default=BUDGET_PATH)
    ap.add_argument("--methods", default="dopri5",
                    help="comma-separated tableau names for per-case rules")
    ap.add_argument("--no-memory", action="store_true")
    args = ap.parse_args(argv)

    methods = tuple(m for m in args.methods.split(",") if m)
    budgets = None if args.write_budgets else load_budgets(args.budgets)
    if budgets is None and not args.write_budgets and args.check:
        print(f"{args.budgets}: no committed budget file; bootstrap with "
              "`python -m repro.analysis --write-budgets`",
              file=sys.stderr)
        return 1

    run_memory = not args.no_memory or args.write_docs_table
    report = run_analysis(budgets, methods=methods, run_memory=run_memory)

    if args.write_budgets:
        write_budgets(report.counts, args.budgets)
        print(f"wrote {len(report.counts)} budgets to {args.budgets}")
    if args.write_docs_table:
        write_docs_table(report.rows)
        print("wrote memory table into docs/analysis.md")

    print(render_report(report))
    if args.check and not report.ok:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
