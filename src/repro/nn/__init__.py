"""Neural-network layer library (pure functions + param pytrees).

Every module exposes ``init_*(key, ...) -> params`` and a matching pure
apply function.  No flax/haiku dependency: params are plain dicts so the
dry-run can abstract-init them with jax.eval_shape and shard them with
explicit PartitionSpecs (parallel/shardings.py).
"""
