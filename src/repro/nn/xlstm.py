"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM training uses the PARALLEL form from the xLSTM paper (App. A):
decay logits l_{ts} = F_t - F_s + i_s with F = cumsum(log-sigmoid(f)),
row-stabilized like flash attention — a quadratic masked attention with a
gate-derived bias, which is why it maps well onto the TPU MXU.  Decode is
the O(1) recurrence on the matrix state (C, n, m), which is what makes the
long_500k cell linear-cost (DESIGN.md §Arch-applicability).

sLSTM is inherently sequential (recurrent R per head); training scans over
time with a rematerialized cell, decode is a single cell step.
State layouts:
  mLSTM: {"C": (B,H,dk,dv), "n": (B,H,dk), "m": (B,H)}
  sLSTM: {"c","n","h": (B,H,dh), "m": (B,H,dh)}
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import dense_init, no_shard, split_keys
from .norm import init_layernorm, layernorm


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int = 4
    m_proj_factor: float = 2.0     # mLSTM up-projection
    s_proj_factor: float = 4.0 / 3.0
    d_conv: int = 4
    # training-time mLSTM evaluation: "chunkwise" (state-passing; wins
    # when S >> dk so quadratic rows dominate) vs "parallel" (masked
    # quadratic form; wins at moderate S because the (dk, dv) state ops
    # and their saved carries cost more than recomputed logit blocks —
    # measured in EXPERIMENTS.md §Perf Cell A).  "auto" switches on
    # sequence length.
    m_form: str = "auto"
    m_chunk: int = 1024
    m_chunkwise_min_s: int = 8192


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: XLSTMConfig, dtype=jnp.float32):
    ks = split_keys(key, 9)
    d = cfg.d_model
    di = int(cfg.m_proj_factor * d)
    H = cfg.n_heads
    return {
        "up": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (cfg.d_conv, di), dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": dense_init(ks[2], (di, di), dtype),
        "wk": dense_init(ks[3], (di, di), dtype),
        "wv": dense_init(ks[4], (di, di), dtype),
        "wi": dense_init(ks[5], (di, H), jnp.float32),
        "wf": dense_init(ks[6], (di, H), jnp.float32),
        "skip_norm": init_layernorm(di, dtype),
        "down": dense_init(ks[7], (di, d), dtype),
    }


def _causal_conv(x, w, b, state=None):
    k = w.shape[0]
    pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype) \
        if state is None else state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b
    return out, (xp[:, -(k - 1):] if k > 1 else None)


def mlstm_forward(p, x, cfg: XLSTMConfig, *, state=None, shard=no_shard):
    B, S, d = x.shape
    H = cfg.n_heads
    di = int(cfg.m_proj_factor * d)
    dh = di // H

    xz = x @ p["up"]
    xb, z = jnp.split(xz, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    cx, new_conv = _causal_conv(xb, p["conv_w"], p["conv_b"], conv_state)
    cx = jax.nn.silu(cx)

    def heads(t):
        return t.reshape(B, S, H, dh).transpose(0, 2, 1, 3)  # (B,H,S,dh)

    q = heads(cx @ p["wq"]) * dh ** -0.5
    k = heads(cx @ p["wk"])
    v = heads(xb @ p["wv"])
    i_gate = (cx @ p["wi"]).transpose(0, 2, 1)               # (B,H,S) f32
    f_gate = (cx @ p["wf"]).transpose(0, 2, 1)

    decode = state is not None and S == 1
    if decode:
        C, n, m = state["C"], state["n"], state["m"]
        logf = jax.nn.log_sigmoid(f_gate[..., 0])            # (B,H)
        logi = i_gate[..., 0]
        m_new = jnp.maximum(logf + m, logi)
        fe = jnp.exp(logf + m - m_new)[..., None, None]
        ie = jnp.exp(logi - m_new)[..., None, None]
        kk, vv, qq = k[:, :, 0], v[:, :, 0], q[:, :, 0]      # (B,H,dh)
        C = fe * C + ie * (kk[..., :, None] * vv[..., None, :])
        n = fe[..., 0] * n + ie[..., 0] * kk
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qq)),
                            jnp.exp(-m_new))[..., None]
        y = jnp.einsum("bhd,bhdv->bhv", qq, C) / denom       # (B,H,dv)
        y = y[:, :, None]                                    # (B,H,1,dh)
        new_state = {"conv": new_conv, "C": C, "n": n, "m": m_new}
    elif (cfg.m_form == "chunkwise"
          or (cfg.m_form == "auto" and S >= cfg.m_chunkwise_min_s)) and \
            S % cfg.m_chunk == 0 and S > cfg.m_chunk:
        y, last_state = _mlstm_chunkwise(q, k, v, i_gate, f_gate,
                                         cfg.m_chunk)
        new_state = None
        if state is not None:
            C, n, m = last_state
            new_state = {"conv": new_conv, "C": C, "n": n, "m": m}
    else:
        logf = jax.nn.log_sigmoid(f_gate)                    # (B,H,S)
        F = jnp.cumsum(logf, axis=-1)
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        spos = jnp.arange(S)[None, :]
        bq = 256 if S % 256 == 0 and S > 256 else S
        nb = S // bq
        qb = q.astype(jnp.float32).reshape(B, H, nb, bq, dh) \
            .transpose(2, 0, 1, 3, 4)
        Fb = F.reshape(B, H, nb, bq).transpose(2, 0, 1, 3)

        @jax.checkpoint
        def one_block(args):
            # per-row normalization is independent, so query-blocking is
            # exact; peak live is (bq, S) per (batch, head).
            qi, Fi, i = args
            lts = Fi[..., :, None] - F[..., None, :] + \
                i_gate[..., None, :]                         # (B,H,bq,S)
            tpos = (i * bq + jnp.arange(bq))[:, None]
            lts = jnp.where(spos[None, None] <= tpos[None, None],
                            lts, -jnp.inf)
            m_row = jnp.max(lts, axis=-1, keepdims=True)
            m_row = jnp.where(jnp.isfinite(m_row), m_row, 0.0)
            Dmat = jnp.exp(lts - m_row)
            Smat = jnp.einsum("bhtd,bhsd->bhts", qi, kf) * Dmat
            denom = jnp.maximum(jnp.abs(jnp.sum(Smat, -1, keepdims=True)),
                                jnp.exp(-m_row))
            return jnp.einsum("bhts,bhsv->bhtv", Smat / denom, vf)

        def bodyfn(_, args):
            return None, one_block(args)

        _, yb = jax.lax.scan(bodyfn, None, (qb, Fb, jnp.arange(nb)))
        y = yb.transpose(1, 2, 0, 3, 4).reshape(B, H, S, dh) \
            .astype(x.dtype)
        new_state = None
        if state is not None:   # prefill: also produce the recurrent state
            ie_all = jnp.exp(i_gate + (F[..., -1:] - F))     # (B,H,S)
            m_fin = jnp.max(i_gate + (F[..., -1:] - F), axis=-1)
            ie_all = jnp.exp(i_gate + (F[..., -1:] - F) - m_fin[..., None])
            C = jnp.einsum("bhs,bhsd,bhsv->bhdv", ie_all,
                           k.astype(jnp.float32), v.astype(jnp.float32))
            n = jnp.einsum("bhs,bhsd->bhd", ie_all, k.astype(jnp.float32))
            new_state = {"conv": new_conv, "C": C, "n": n, "m": m_fin}

    y = y.transpose(0, 2, 1, 3).reshape(B, S, di).astype(x.dtype)
    y = layernorm(p["skip_norm"], y) + cx        # gated skip (xLSTM style)
    y = y * jax.nn.silu(z)
    out = y @ p["down"]
    return shard(out, ("batch", "seq", "embed")), new_state


def init_mlstm_state(cfg: XLSTMConfig, batch: int, dtype=jnp.float32):
    di = int(cfg.m_proj_factor * cfg.d_model)
    H = cfg.n_heads
    dh = di // H
    return {"conv": jnp.zeros((batch, cfg.d_conv - 1, di), dtype),
            "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.zeros((batch, H), jnp.float32)}


def _mlstm_chunkwise(q, k, v, i_gate, f_gate, Q: int):
    """Chunkwise-recurrent mLSTM (xLSTM App. A), numerically identical to
    the parallel form (tests assert allclose).

    q,k,v: (B,H,S,dh) (q pre-scaled); i_gate,f_gate: (B,H,S) f32.
    Sequence is split into S/Q chunks; within a chunk the masked quadratic
    form runs on (Q,Q) logits; across chunks a stabilized matrix state
    (C, n, m) carries the history:

        C_prev = sum_{s<start} exp(F_start - F_s + i_s - m_prev) k_s v_s^T

    FLOPs per token drop from O(2*S*dh) to O(2*Q*dh + 2*dk*dv/... state
    read+write amortized): the §Perf Cell-A optimization.
    Returns (y: (B,H,S,dh), (C,n,m) final carry)."""
    B, H, S, dh = q.shape
    nc = S // Q
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))

    def split(t):
        return t.reshape(t.shape[0], t.shape[1], nc, Q, *t.shape[3:]) \
            .transpose(2, 0, 1, 3, *range(4, t.ndim + 1))

    qs, ks, vs = split(qf), split(kf), split(vf)         # (nc,B,H,Q,dh)
    is_, fs = split(i_gate.astype(jnp.float32)), split(logf)  # (nc,B,H,Q)

    mask = jnp.tril(jnp.ones((Q, Q), bool))

    @jax.checkpoint
    def chunk(carry, inp):
        C, n, m = carry                                   # (B,H,dk,dv) ...
        qc, kc, vc, ic, fc = inp
        b = jnp.cumsum(fc, axis=-1)                       # (B,H,Q)
        Btot = b[..., -1:]
        # intra-chunk logits l_ts = b_t - b_s + i_s  (s <= t)
        lts = b[..., :, None] - b[..., None, :] + ic[..., None, :]
        lts = jnp.where(mask, lts, -jnp.inf)
        m_intra = jnp.max(lts, axis=-1)                   # (B,H,Q)
        m_inter = b + m[..., None]                        # (B,H,Q)
        m_t = jnp.maximum(m_inter, m_intra)
        m_t = jnp.where(jnp.isfinite(m_t), m_t, 0.0)
        D = jnp.exp(lts - m_t[..., None])
        Smat = jnp.einsum("bhtd,bhsd->bhts", qc, kc) * D
        w_inter = jnp.exp(m_inter - m_t)                  # (B,H,Q)
        h = jnp.einsum("bhts,bhsv->bhtv", Smat, vc) + \
            w_inter[..., None] * jnp.einsum("bhtd,bhdv->bhtv", qc, C)
        den = jnp.sum(Smat, axis=-1) + \
            w_inter * jnp.einsum("bhtd,bhd->bht", qc, n)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        y = h / den[..., None]
        # carry update relative to chunk end
        dec = Btot - b + ic                               # (B,H,Q)
        m_new = jnp.maximum(Btot[..., 0] + m, jnp.max(dec, axis=-1))
        wk = jnp.exp(dec - m_new[..., None])              # (B,H,Q)
        wC = jnp.exp(Btot[..., 0] + m - m_new)[..., None, None]
        C = wC * C + jnp.einsum("bhs,bhsd,bhsv->bhdv", wk, kc, vc)
        n = wC[..., 0] * n + jnp.einsum("bhs,bhsd->bhd", wk, kc)
        return (C, n, m_new), y

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    (C, n, m), ys = jax.lax.scan(chunk, (C0, n0, m0),
                                 (qs, ks, vs, is_, fs))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, S, dh).astype(q.dtype)
    return y, (C, n, m)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: XLSTMConfig, dtype=jnp.float32):
    ks = split_keys(key, 7)
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    # round the up-projection to a multiple of 128 (TPU lane width and
    # TP-shardability over a 16-way model axis)
    df = max(128, -(-int(cfg.s_proj_factor * d) // 128) * 128)
    return {
        "wx": dense_init(ks[0], (d, 4 * d), dtype),       # i,f,z,o pre-acts
        "r": dense_init(ks[1], (H, dh, 4 * dh), jnp.float32),  # recurrent
        "b": jnp.zeros((4 * d,), jnp.float32),
        "up1": dense_init(ks[2], (d, df), dtype),
        "up2": dense_init(ks[3], (d, df), dtype),
        "down": dense_init(ks[4], (df, d), dtype),
        "out_norm": init_layernorm(d, dtype),
    }


def _slstm_cell(p, xt, st, H, dh):
    """One sLSTM time step. xt: (B, 4d) pre-activations from input."""
    c, n, h, m = st["c"], st["n"], st["h"], st["m"]       # (B,H,dh)
    rec = jnp.einsum("bhd,hdk->bhk", h, p["r"])           # (B,H,4dh)
    pre = xt.reshape(xt.shape[0], H, 4 * dh) + rec + \
        p["b"].reshape(H, 4 * dh)
    i_, f_, z_, o_ = jnp.split(pre, 4, axis=-1)           # (B,H,dh)
    logf = jax.nn.log_sigmoid(f_)
    m_new = jnp.maximum(logf + m, i_)
    ie = jnp.exp(i_ - m_new)
    fe = jnp.exp(logf + m - m_new)
    c = fe * c + ie * jnp.tanh(z_)
    n = jnp.maximum(fe * n + ie, 1e-6)
    h = jax.nn.sigmoid(o_) * c / n
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_forward(p, x, cfg: XLSTMConfig, *, state=None, shard=no_shard):
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    xw = (x @ p["wx"]).astype(jnp.float32)                # (B,S,4d)

    if state is None:
        st0 = init_slstm_state(cfg, B)
    else:
        st0 = {k: v for k, v in state.items()}

    if S == 1 and state is not None:
        st = _slstm_cell(p, xw[:, 0], st0, H, dh)
        hs = st["h"][:, None]                             # (B,1,H,dh)
        new_state = st
    else:
        def step(st, xt):
            st = _slstm_cell(p, xt, st, H, dh)
            return st, st["h"]

        # two-level time scan: the outer (chunk) scan saves carries only
        # at chunk boundaries and remats the inner steps — without this,
        # backward retains the 4-tuple cell state at EVERY timestep
        # (the xlstm train_4k memory driver found in §Perf Cell A)
        cs = 256 if S % 256 == 0 and S > 256 else S
        nc = S // cs
        xw_c = xw.transpose(1, 0, 2).reshape(nc, cs, B, xw.shape[-1])

        @jax.checkpoint
        def chunk(st, xc):
            return jax.lax.scan(step, st, xc)

        st, hs = jax.lax.scan(chunk, st0, xw_c)
        hs = hs.reshape(S, B, H, dh).transpose(1, 0, 2, 3)  # (B,S,H,dh)
        new_state = st if state is not None else None

    y = hs.reshape(B, -1, d).astype(x.dtype)
    y = layernorm(p["out_norm"], y)
    y = (jax.nn.gelu(y @ p["up1"]) * (y @ p["up2"])) @ p["down"]
    return shard(y, ("batch", "seq", "embed")), new_state


def init_slstm_state(cfg: XLSTMConfig, batch: int):
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z, "m": z}
