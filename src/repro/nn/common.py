"""Shared helpers: initializers, sharding hooks, dtype policy."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

Sharder = Callable[[jnp.ndarray, tuple], jnp.ndarray]
# sharder(x, logical_axes) -> x with a sharding constraint attached.


def no_shard(x, logical_axes):
    return x


@dataclasses.dataclass(frozen=True)
class DtypePolicy:
    params: jnp.dtype = jnp.float32
    compute: jnp.dtype = jnp.float32
    logits: jnp.dtype = jnp.float32

    @staticmethod
    def bf16():
        return DtypePolicy(params=jnp.bfloat16, compute=jnp.bfloat16,
                           logits=jnp.float32)


def dense_init(key, shape, dtype, in_axis: int = 0):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))
