"""RMSNorm (kernel-dispatched) and LayerNorm."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ops as kops


def init_rmsnorm(d, dtype=jnp.float32):
    return {"w": jnp.ones((d,), dtype)}


def rmsnorm(p, x, *, eps=1e-6, use_pallas=None):
    return kops.rms_norm(x, p["w"], eps=eps, use_pallas=use_pallas)


def init_layernorm(d, dtype=jnp.float32):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p, x, *, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jnp.reciprocal(jnp.sqrt(var + eps))
    return (out * p["w"].astype(jnp.float32)
            + p["b"].astype(jnp.float32)).astype(x.dtype)
