"""SwiGLU MLP (llama-family feed-forward)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, no_shard, split_keys


def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = split_keys(key, 3)
    return {
        "wg": dense_init(ks[0], (d_model, d_ff), dtype),
        "wu": dense_init(ks[1], (d_model, d_ff), dtype),
        "wd": dense_init(ks[2], (d_ff, d_model), dtype),
    }


def swiglu(p, x, *, shard=no_shard):
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    h = shard(h, ("batch", "seq", "ffn"))
    out = h @ p["wd"]
    return shard(out, ("batch", "seq", "embed"))
