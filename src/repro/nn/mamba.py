"""Mamba-1 selective SSM block (jamba's recurrent layer).

Training uses a chunked parallel scan: the sequence is split into chunks;
within a chunk the recurrence h_t = a_t * h_{t-1} + b_t is evaluated with an
associative scan (materializing (B, chunk, d_inner, d_state) transiently,
rematerialized in backward), and chunk boundary states are carried by an
outer lax.scan.  This bounds live memory to O(B * chunk * d_inner * N) —
the TPU-friendly adaptation of the CUDA fused scan (DESIGN.md §2).

Decode is the O(1) recurrent update with a rolling conv buffer.
State: {"conv": (B, k-1, d_inner), "ssm": (B, d_inner, d_state)}.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import dense_init, no_shard, split_keys


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0        # 0 => ceil(d_model/16)
    chunk: int = 256

    @property
    def d_inner(self):
        return self.expand * self.d_model

    @property
    def rank(self):
        return self.dt_rank or -(-self.d_model // 16)


def init_mamba(key, cfg: MambaConfig, dtype=jnp.float32):
    ks = split_keys(key, 7)
    d, di, N, R = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.rank
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (cfg.d_conv, di), dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, R + 2 * N), dtype),
        "dt_proj": dense_init(ks[3], (R, di), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))
        ).astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], (di, d), dtype),
    }


def _causal_conv(x, w, b, state=None):
    """x: (B,S,di); w: (k,di) depthwise. state: (B,k-1,di) prior inputs."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)           # (B, S+k-1, di)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return out, new_state


def _ssm_scan_chunked(dt, Bc, Cc, xb, A, h0, chunk: int):
    """Fused selective scan: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t,
    y_t = C_t . h_t — chunked over the sequence with the state tensor and
    the (dt*A) discretization materialized ONE CHUNK AT A TIME (the
    TPU-side equivalent of the fused CUDA scan; see module docstring).

    dt, xb: (B,S,di); Bc, Cc: (B,S,N); A: (di,N); h0: (B,di,N).
    Returns (y: (B,S,di) f32, h_final)."""
    B, S, di = dt.shape
    N = A.shape[1]
    cs = min(chunk, S)
    assert S % cs == 0, (S, cs)
    n_chunks = S // cs

    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    def chunk_body(h, inp):
        dtc, bcc, ccc, xbc = inp                       # (B, cs, ...)
        da = jnp.exp(dtc[..., None] * A[None, None])   # (B,cs,di,N)
        db = dtc[..., None] * bcc[:, :, None, :] * xbc[..., None]
        aa, bb = jax.lax.associative_scan(op, (da, db), axis=1)
        hs = aa * h[:, None] + bb                      # (B,cs,di,N)
        y = jnp.einsum("bsdn,bsn->bsd", hs, ccc)
        return hs[:, -1], y

    def split(t):
        return t.reshape(t.shape[0], n_chunks, cs, *t.shape[2:]) \
            .transpose(1, 0, 2, *range(3, t.ndim + 1))

    hF, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0,
                          (split(dt), split(Bc), split(Cc), split(xb)))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    return y, hF


def mamba_forward(p, x, cfg: MambaConfig, *, state=None, shard=no_shard):
    """x: (B,S,d). state None => training/prefill (returns final state when
    a state dict is passed for prefill); decode when S==1 and state given."""
    B, S, d = x.shape
    di, N, R = cfg.d_inner, cfg.d_state, cfg.rank
    xz = x @ p["in_proj"]
    xb, z = jnp.split(xz, 2, axis=-1)                  # (B,S,di) each
    xb = shard(xb, ("batch", "seq", "ffn"))

    decode = state is not None and S == 1
    conv_state = state["conv"] if state is not None else None
    xb, new_conv = _causal_conv(xb, p["conv_w"], p["conv_b"], conv_state)
    xb = jax.nn.silu(xb)

    proj = xb @ p["x_proj"]
    dt, Bc, Cc = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])   # (B,S,di)
    A = -jnp.exp(p["A_log"])                                  # (di,N)

    if decode:
        da0 = jnp.exp(dt[:, 0, :, None] * A[None])            # (B,di,N)
        db0 = dt[:, 0, :, None] * Bc[:, 0, None, :] * xb[:, 0, :, None]
        h0 = state["ssm"]
        h = da0 * h0 + db0                                    # (B,di,N)
        y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0])[:, None]
        new_state = {"conv": new_conv, "ssm": h}
    else:
        h0 = jnp.zeros((B, di, N), jnp.float32)
        y, hF = _ssm_scan_chunked(dt.astype(jnp.float32),
                                  Bc.astype(jnp.float32),
                                  Cc.astype(jnp.float32),
                                  xb.astype(jnp.float32), A, h0,
                                  cfg.chunk)
        new_state = {"conv": new_conv, "ssm": hF} \
            if state is not None else None
    y = y + xb * p["D"]
    y = (y * jax.nn.silu(z)).astype(x.dtype)
    out = y @ p["out_proj"]
    return shard(out, ("batch", "seq", "embed")), new_state


def init_mamba_state(cfg: MambaConfig, batch: int, dtype=jnp.float32):
    return {"conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
            "ssm": jnp.zeros((batch, cfg.d_inner, cfg.d_state), dtype)}
