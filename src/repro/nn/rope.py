"""Rotary position embeddings (full and partial)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0,
               rotary_dim: int | None = None):
    rd = rotary_dim if rotary_dim is not None else head_dim
    inv = 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))
    return inv  # (rd/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, inv_freqs: jnp.ndarray,
               rotary_dim: int | None = None) -> jnp.ndarray:
    """x: (B, H, S, D); positions: (S,) or (B, S) absolute positions."""
    D = x.shape[-1]
    rd = rotary_dim if rotary_dim is not None else D
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * inv_freqs[None, :]
        ang = ang[None, None]                     # (1,1,S,rd/2)
    else:
        ang = positions.astype(jnp.float32)[:, None, :, None] * \
            inv_freqs[None, None, None, :]        # (B,1,S,rd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr = x[..., :rd].astype(jnp.float32)
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    rot = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    if rd < D:
        rot = jnp.concatenate([rot, x[..., rd:].astype(jnp.float32)], -1)
    return rot.astype(x.dtype)
