"""Mixture-of-Experts with sort-based dispatch (MegaBlocks/MaxText style).

Routing is per sequence row (vmapped over batch), so the token sort never
crosses a data shard — under pjit the dispatch stays local to each data-
parallel shard and the only collective added by the MoE layer is the same
all-reduce a tensor-parallel dense MLP needs (expert d_ff is TP-sharded on
the ``model`` axis; ``expert`` axis sharding = EP is a config option explored
in §Perf).

Compute cost is ACTIVE-ONLY: tokens are gathered into (E, C, d) buffers
(C = capacity) and hit one batched GEMM per projection; overflow tokens are
dropped (standard capacity-factor semantics), and the auxiliary load-balance
loss (Switch/GShard) discourages overflow.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .common import dense_init, no_shard, split_keys


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                  # per-expert hidden
    n_experts: int
    top_k: int
    n_shared: int = 0          # deepseek-style always-on shared experts
    shared_d_ff: int = 0       # hidden size of the fused shared expert
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


def init_moe(key, cfg: MoEConfig, dtype=jnp.float32):
    ks = split_keys(key, 5)
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "wg": dense_init(ks[1], (E, d, f), dtype),
        "wu": dense_init(ks[2], (E, d, f), dtype),
        "wd": dense_init(ks[3], (E, f, d), dtype),
    }
    if cfg.n_shared > 0:
        sf = cfg.shared_d_ff or cfg.d_ff * cfg.n_shared
        sks = split_keys(ks[4], 3)
        p["shared"] = {
            "wg": dense_init(sks[0], (d, sf), dtype),
            "wu": dense_init(sks[1], (d, sf), dtype),
            "wd": dense_init(sks[2], (sf, d), dtype),
        }
    return p


def _dispatch_row(x_row, gate_idx, gate_w, E: int, C: int):
    """Build gather indices for one sequence row.

    x_row: (S, d); gate_idx/gate_w: (S, k). Returns
    (slot_token: (E, C) int32 token ids or S (=dropped sentinel),
     slot_gate:  (E, C) f32 combine weights).
    """
    S, k = gate_idx.shape
    flat_e = gate_idx.reshape(-1)                       # (S*k,)
    flat_t = jnp.repeat(jnp.arange(S), k)               # token of each slot
    flat_w = gate_w.reshape(-1)
    # rank of each (token,expert) assignment within its expert, via a
    # sort + segmented-position trick: O(S*k) memory (NOT (S*k, E) one-hot)
    n = flat_e.shape[0]
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    pos = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, pos, jnp.int32(0)))
    rank_sorted = (pos - seg_start).astype(jnp.int32)
    my_rank = jnp.zeros((n,), jnp.int32).at[sort_idx].set(rank_sorted)
    keep = my_rank < C
    slot = (flat_e.astype(jnp.int32) * C + my_rank)               # (S*k,)
    slot = jnp.where(keep, slot, E * C)                           # overflow
    slot_token = jnp.full((E * C + 1,), S, jnp.int32) \
        .at[slot].set(jnp.where(keep, flat_t.astype(jnp.int32), S)) \
        [:E * C]
    slot_gate = jnp.zeros((E * C + 1,), jnp.float32) \
        .at[slot].set(jnp.where(keep, flat_w.astype(jnp.float32),
                                0.0))[:E * C]
    return slot_token.reshape(E, C), slot_gate.reshape(E, C)


def moe_ffn(p, x, cfg: MoEConfig, *, shard=no_shard):
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(1, int(S * k * cfg.capacity_factor / E))

    logits = (x.astype(jnp.float32) @ p["router"])       # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, k)           # (B,S,k)
    gate_w = gate_w / jnp.maximum(
        jnp.sum(gate_w, -1, keepdims=True), 1e-9)

    # ---- aux load-balance loss (GShard/Switch) --------------------------
    me = jnp.mean(probs, axis=(0, 1))                                  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=2),
        axis=(0, 1))                                                   # (E,)
    aux = (cfg.router_aux_weight * E * jnp.sum(me * ce)) \
        .astype(jnp.float32)

    slot_token, slot_gate = jax.vmap(
        lambda xr, gi, gw: _dispatch_row(xr, gi, gw, E, C)
    )(x, gate_idx, gate_w)                               # (B,E,C) each

    # gather tokens (out-of-range id S clamps to row S-1, zero gate later);
    # flat per-row gather — NEVER broadcasts x to (B, E, S, d)
    ids = jnp.minimum(slot_token, S - 1).reshape(B, E * C)
    xe = jax.vmap(lambda xb, ib: jnp.take(xb, ib, axis=0))(x, ids)
    xe = xe.reshape(B, E, C, d)
    xe = shard(xe, ("batch", "experts", None, "embed"))

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["wg"])) * \
        jnp.einsum("becd,edf->becf", xe, p["wu"])
    h = shard(h, ("batch", "experts", None, "ffn"))
    ye = jnp.einsum("becf,efd->becd", h, p["wd"])        # (B,E,C,d)
    ye = ye * slot_gate[..., None].astype(ye.dtype)

    # scatter-add back to tokens
    flat = ye.reshape(B, E * C, d)
    ids = slot_token.reshape(B, E * C)
    y = jnp.zeros((B, S + 1, d), flat.dtype)
    y = jax.vmap(lambda yb, ib, fb: yb.at[ib].add(fb))(y, ids, flat)
    y = y[:, :S]

    if cfg.n_shared > 0:
        sp = p["shared"]
        hs = jax.nn.silu(x @ sp["wg"]) * (x @ sp["wu"])
        hs = shard(hs, ("batch", "seq", "ffn"))
        y = y + hs @ sp["wd"]
    return shard(y, ("batch", "seq", "embed")), aux
