"""Attention blocks: GQA (with qk-norm, sliding window, partial rope), MLA.

Shapes: activations (B, S, d_model); heads layout (B, H, S, Dh) internally.
KV caches: GQA -> {"k": (B, Smax, Hkv, Dh), "v": ...};
           MLA -> {"ckv": (B, Smax, kv_lora), "kr": (B, Smax, rope_dim)}
(the MLA cache stores the *compressed* latent — the paper-faithful memory win
of DeepSeek-V2 — and decode uses the absorbed-matmul formulation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from .common import dense_init, no_shard, split_keys
from .norm import init_rmsnorm, rmsnorm
from .rope import apply_rope, rope_freqs


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    window: Optional[int] = None
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0           # stablelm uses 0.25
    # MLA (deepseek) fields
    mla: bool = False
    kv_lora: int = 512
    q_lora: int = 0                    # 0 = no q compression (v2-lite)
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: AttnConfig, dtype=jnp.float32):
    ks = split_keys(key, 6)
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], (d, H * Dh), dtype),
        "wk": dense_init(ks[1], (d, Hkv * Dh), dtype),
        "wv": dense_init(ks[2], (d, Hkv * Dh), dtype),
        "wo": dense_init(ks[3], (H * Dh, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(Dh, dtype)
        p["k_norm"] = init_rmsnorm(Dh, dtype)
    return p


def gqa_attention(p, x, cfg: AttnConfig, *, positions=None, cache=None,
                  pos=None, shard=no_shard, use_pallas=None,
                  causal: bool = True):
    """x: (B, S, d). Training/prefill when cache is None or being filled;
    decode when ``pos`` (scalar int) is given with S == 1.

    Returns (out, new_cache_or_None).
    """
    B, S, d = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rd = int(Dh * cfg.rotary_pct)
    inv = rope_freqs(Dh, cfg.rope_theta, rd)

    q = (x @ p["wq"]).reshape(B, S, H, Dh)
    k = (x @ p["wk"]).reshape(B, S, Hkv, Dh)
    v = (x @ p["wv"]).reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, use_pallas=use_pallas)
        k = rmsnorm(p["k_norm"], k, use_pallas=use_pallas)
    q = q.transpose(0, 2, 1, 3)   # (B,H,S,Dh)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    q = shard(q, ("batch", "heads", "seq", "head_dim"))
    k = shard(k, ("batch", "kv_heads", "seq", "head_dim"))

    if pos is None:
        pp = positions if positions is not None else jnp.arange(S)
        q = apply_rope(q, pp, inv, rd)
        k = apply_rope(k, pp, inv, rd)
        new_cache = None
        if cache is not None:  # prefill: write into the cache buffer
            cache_axes = ("batch", "seq_carry", "cache_heads", "head_dim")
            new_cache = {
                "k": shard(jax.lax.dynamic_update_slice(
                    cache["k"], k.transpose(0, 2, 1, 3).astype(
                        cache["k"].dtype), (0, 0, 0, 0)), cache_axes),
                "v": shard(jax.lax.dynamic_update_slice(
                    cache["v"], v.transpose(0, 2, 1, 3).astype(
                        cache["v"].dtype), (0, 0, 0, 0)), cache_axes),
            }
        out = kops.attention(q, k, v, causal=causal, window=cfg.window,
                             q_offset=0, use_pallas=use_pallas)
    else:
        # decode: S == 1, append to cache at index ``pos``
        ppos = jnp.reshape(pos, (1,))
        q = apply_rope(q, ppos, inv, rd)
        k = apply_rope(k, ppos, inv, rd)
        z = jnp.zeros((), dtype=jnp.asarray(pos).dtype)
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.transpose(0, 2, 1, 3).astype(cache["k"].dtype),
            (z, pos, z, z))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.transpose(0, 2, 1, 3).astype(cache["v"].dtype),
            (z, pos, z, z))
        new_cache = {"k": ck, "v": cv}
        # decode: no head-repeat, no f32 cache copy (see ref docstring)
        out = kref.decode_attention_ref(q, ck, cv, pos, window=cfg.window)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * Dh)
    out = out @ p["wo"]
    return shard(out, ("batch", "seq", "embed")), new_cache


def init_gqa_cache(cfg: AttnConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, max_len, Hkv, Dh), dtype),
            "v": jnp.zeros((batch, max_len, Hkv, Dh), dtype)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: AttnConfig, dtype=jnp.float32):
    ks = split_keys(key, 8)
    d, H = cfg.d_model, cfg.n_heads
    qd = cfg.nope_head_dim + cfg.rope_head_dim
    p = {
        "wq": dense_init(ks[0], (d, H * qd), dtype),
        "wdkv": dense_init(ks[1], (d, cfg.kv_lora), dtype),
        "kv_norm": init_rmsnorm(cfg.kv_lora, dtype),
        "wuk": dense_init(ks[2], (cfg.kv_lora, H * cfg.nope_head_dim), dtype),
        "wuv": dense_init(ks[3], (cfg.kv_lora, H * cfg.v_head_dim), dtype),
        "wkr": dense_init(ks[4], (d, cfg.rope_head_dim), dtype),
        "wo": dense_init(ks[5], (H * cfg.v_head_dim, d), dtype),
    }
    return p


def mla_attention(p, x, cfg: AttnConfig, *, positions=None, cache=None,
                  pos=None, shard=no_shard, use_pallas=None):
    """DeepSeek-V2 MLA. Prefill materializes per-head K/V (flash-compatible);
    decode runs the absorbed formulation against the compressed cache."""
    B, S, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    inv = rope_freqs(dr, cfg.rope_theta, dr)
    scale = (dn + dr) ** -0.5

    q = (x @ p["wq"]).reshape(B, S, H, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    ckv = rmsnorm(p["kv_norm"], x @ p["wdkv"], use_pallas=use_pallas)
    kr = (x @ p["wkr"]).reshape(B, S, 1, dr).transpose(0, 2, 1, 3)

    if pos is None:
        pp = positions if positions is not None else jnp.arange(S)
        q_rope = apply_rope(q_rope, pp, inv)
        kr = apply_rope(kr, pp, inv)
        k_nope = (ckv @ p["wuk"]).reshape(B, S, H, dn).transpose(0, 2, 1, 3)
        v = (ckv @ p["wuv"]).reshape(B, S, H, dv).transpose(0, 2, 1, 3)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr, (B, H, S, dr))], -1)
        qq = jnp.concatenate([q_nope, q_rope], -1)
        qq = shard(qq, ("batch", "heads", "seq", "head_dim"))
        # v is dv-dim; pad to qk dim not needed: ops.attention requires same
        # D for q/k only; v can differ -> use ref einsum path via kops with
        # v dim dv (flash kernel assumes same D; use ref for MLA).
        out = kref.attention_ref(qq, k, v, causal=True, q_offset=0,
                                 scale=scale)
        new_cache = None
        if cache is not None:
            new_cache = {
                "ckv": shard(jax.lax.dynamic_update_slice(
                    cache["ckv"], ckv.astype(cache["ckv"].dtype),
                    (0, 0, 0)), ("batch", "seq_carry", "embed")),
                "kr": shard(jax.lax.dynamic_update_slice(
                    cache["kr"],
                    kr[:, 0].astype(cache["kr"].dtype), (0, 0, 0)),
                    ("batch", "seq_carry", "head_dim")),
            }
    else:
        ppos = jnp.reshape(pos, (1,))
        q_rope = apply_rope(q_rope, ppos, inv)
        kr = apply_rope(kr, ppos, inv)
        z = jnp.zeros((), dtype=jnp.asarray(pos).dtype)
        new_cache = {
            "ckv": jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (z, pos, z)),
            "kr": jax.lax.dynamic_update_slice(
                cache["kr"], kr[:, 0].astype(cache["kr"].dtype),
                (z, pos, z)),
        }
        C = new_cache["ckv"].astype(jnp.float32)          # (B, Smax, dl)
        KR = new_cache["kr"].astype(jnp.float32)          # (B, Smax, dr)
        # absorbed: q_eff[h] = wuk[h]^T q_nope[h]  -> attend over latent
        wuk = p["wuk"].reshape(cfg.kv_lora, H, dn).astype(jnp.float32)
        q_abs = jnp.einsum("bhsd,lhd->bhsl", q_nope.astype(jnp.float32),
                           wuk)                            # (B,H,1,dl)
        s_lat = jnp.einsum("bhsl,btl->bhst", q_abs, C)
        s_rot = jnp.einsum("bhsd,btd->bhst", q_rope.astype(jnp.float32), KR)
        s = (s_lat + s_rot) * scale
        kpos = jnp.arange(C.shape[1])[None, None, None, :]
        s = jnp.where(kpos <= pos, s, -jnp.inf)
        pr = jax.nn.softmax(s, axis=-1)
        lat = jnp.einsum("bhst,btl->bhsl", pr, C)          # (B,H,1,dl)
        wuv = p["wuv"].reshape(cfg.kv_lora, H, dv).astype(jnp.float32)
        out = jnp.einsum("bhsl,lhd->bhsd", lat, wuv).astype(x.dtype)

    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * dv)
    out = out @ p["wo"]
    return shard(out, ("batch", "seq", "embed")), new_cache


def init_mla_cache(cfg: AttnConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora), dtype),
            "kr": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype)}
