"""Elastic restart: reshard a train state onto a different mesh.

Checkpoints store full logical arrays, so elasticity reduces to device_put
with the new mesh's shardings.  ``reshard_state`` also handles LIVE state
(e.g. shrinking from 512 to 256 chips after a pod loss): jax.device_put on
committed arrays performs the resharding collectives.

``specs`` must mirror ``state``'s pytree structure with a PartitionSpec per
leaf (e.g. ``parallel.state_specs``); the traversal follows ``state``'s
treedef, so registered nodes like ``train.TrainState`` reshard like any
other pytree — the (4,) -> (2, 2) elasticity test in tests/test_failures.py
pins exactly that round-trip.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec


def mesh_shardings(mesh, specs: Any):
    """NamedSharding tree from a PartitionSpec tree (specs are tuple
    subclasses, so they must be treated as leaves explicitly)."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, PartitionSpec))


def reshard_state(state: Any, mesh, specs: Any):
    """Move/reshard every leaf of ``state`` to ``mesh`` per ``specs``."""
    def put(leaf, spec):
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    # tree_map slices ``specs`` by ``state``'s treedef, so PartitionSpec
    # leaves (tuple subclasses) are never descended into
    return jax.tree_util.tree_map(put, state, specs)
