"""Elastic restart: reshard a train state onto a different mesh.

Checkpoints store full logical arrays, so elasticity reduces to device_put
with the new mesh's shardings.  ``reshard_state`` also handles LIVE state
(e.g. shrinking from 512 to 256 chips after a pod loss): jax.device_put on
committed arrays performs the resharding collectives.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding


def reshard_state(state: Any, mesh, specs: Any):
    """Move/reshard every leaf of ``state`` to ``mesh`` per ``specs``."""
    def put(leaf, spec):
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(
        put, state, specs,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))
