"""Failure handling: bounded retry around the train step + straggler notes.

On a real TPU fleet the failure modes are (a) preempted/failed hosts -> the
coordinator restarts the slice and every worker resumes from the newest
valid checkpoint (launch/train.py does exactly that on boot), (b) transient
collective timeouts -> bounded retry below, (c) stragglers -> mitigated
structurally: synchronous data parallelism with per-pod TP means a slow
chip only stalls its own all-reduce; the launcher sets XLA's
latency-hiding-scheduler + collective-timeout flags, and the data pipeline
is keyed by (step, host) so any restart replays identical batches.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

log = logging.getLogger("repro.failures")


@dataclasses.dataclass(frozen=True)
class RetryConfig:
    max_retries: int = 3
    backoff_s: float = 1.0
    retryable: tuple = (RuntimeError,)


def run_with_retries(fn: Callable, cfg: RetryConfig = RetryConfig(),
                     on_failure: Callable = None, sleep: Callable = None):
    """Run fn(); on a retryable error call on_failure() (e.g. restore from
    checkpoint) and retry with linear backoff.  Raises after max_retries.

    Contract (property-tested in tests/test_failures.py):
      * ``on_failure`` is invoked exactly once per FAILED attempt —
        including the final one whose exception propagates;
      * backoff before retry k (1-based) is ``backoff_s * k`` and is paid
        only before attempts that actually happen (never after the last);
      * exceptions outside ``cfg.retryable`` propagate unwrapped
        immediately, with no on_failure call and no sleep;
      * success after k <= max_retries failures returns fn()'s value.

    ``sleep`` (default ``time.sleep``) is injectable so tests can observe
    the schedule without waiting it out.
    """
    if sleep is None:
        sleep = time.sleep
    attempt = 0
    while True:
        try:
            return fn()
        except cfg.retryable as e:
            attempt += 1
            if on_failure is not None:
                on_failure()
            if attempt > cfg.max_retries:
                raise
            log.warning("step failed (%s); retry %d/%d", e, attempt,
                        cfg.max_retries)
            sleep(cfg.backoff_s * attempt)
