"""Fault-tolerant checkpointing: atomic, keep-last-k, async, resharding.

Layout:  <dir>/step_<N>/host_<i>.npz  +  <dir>/step_<N>/MANIFEST.json
The manifest is written LAST (atomic rename), so a checkpoint directory is
valid iff the manifest exists — a crash mid-write can never be mistaken for
a complete checkpoint, and restore() simply picks the newest valid step.
Stale ``.tmp_step_*`` directories left by a crash mid-write are swept on
init and before every save (they are invisible to restore either way, but
a crash loop must not leak disk).

Async saves overlap the next train step: ``save(..., block=False)`` pulls
the leaves to host synchronously (so donated device buffers are safe to
reuse immediately) and writes in a background thread — the caller's stall
is the host transfer, not the file I/O (measured by
benchmarks/bench_checkpoint.py).  ``REPRO_CKPT_WRITE_DELAY_S`` (or the
``write_delay_s`` arg) injects a delay between the array write and the
manifest publish — the fault-injection harness uses it to SIGKILL a run
mid async save and prove the resume contract (tests/test_failures.py).

Arrays are saved as full logical values (this container is single-host; the
multi-host path shards by leaf hash across hosts — the code paths are the
same, each host just filters its own leaves).  On restore the arrays are
device_put against the CURRENT mesh's shardings, so restoring onto a
different device count / mesh shape (elastic restart) is free — see
runtime/elastic.py and the elasticity test.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree_util.tree_flatten(state)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False, host_id: int = 0,
                 n_hosts: int = 1, write_delay_s: Optional[float] = None):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self.host_id = host_id
        self.n_hosts = n_hosts
        if write_delay_s is None:
            write_delay_s = float(
                os.environ.get("REPRO_CKPT_WRITE_DELAY_S", "0") or 0)
        self.write_delay_s = write_delay_s
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)
        self._clean_stale_tmp()

    def _clean_stale_tmp(self) -> None:
        """Remove ``.tmp_step_*`` leftovers from a crash mid-write.

        Safe to call before starting a write: within one Checkpointer only
        one writer runs at a time (``save`` joins the previous thread), so
        any tmp dir present here belongs to a dead process.
        """
        for name in os.listdir(self.dir):
            if name.startswith(".tmp_step_"):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    # -- save -------------------------------------------------------------
    def save(self, step: int, state: Any, block: bool = True):
        leaves, _ = _flatten(state)
        arrays = [np.asarray(l) for l in leaves]  # pull off device

        def _write():
            self._clean_stale_tmp()
            tmp = os.path.join(self.dir, f".tmp_step_{step}_{self.host_id}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"host_{self.host_id}.npz"),
                     **{f"leaf_{i}": a for i, a in enumerate(arrays)})
            if self.write_delay_s:   # fault-injection window (tests)
                time.sleep(self.write_delay_s)
            manifest = {"step": step, "n_leaves": len(arrays),
                        "n_hosts": self.n_hosts, "time": time.time()}
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)   # atomic publish
            self._gc()

        if self.async_save:
            self.wait()
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
            if block:
                self.wait()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def list_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "MANIFEST.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of ``like``.  ``shardings`` (optional
        tree of NamedSharding) reshards onto the current mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        stepdir = os.path.join(self.dir, f"step_{step}")
        leaves, treedef = _flatten(like)
        with open(os.path.join(stepdir, "MANIFEST.json")) as f:
            manifest = json.load(f)
        if manifest.get("n_leaves") != len(leaves):
            raise ValueError(
                f"checkpoint step {step} in {self.dir} holds "
                f"{manifest.get('n_leaves')} leaves but the restore target "
                f"``like`` has {len(leaves)}: restore must be given the "
                "same train-state pytree structure that was saved "
                "(shape-contract mismatch, not a corrupt checkpoint)")
        data = np.load(os.path.join(stepdir, f"host_{self.host_id}.npz"))
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: isinstance(
                    x, jax.sharding.Sharding))
        else:
            sh_leaves = [None] * len(leaves)
        out = []
        for i, (l, sh) in enumerate(zip(leaves, sh_leaves)):
            arr = data[f"leaf_{i}"]
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr, dtype=l.dtype))
        return jax.tree_util.tree_unflatten(treedef, out), step
