"""Fault-tolerant checkpointing: atomic, keep-last-k, async, resharding.

Layout:  <dir>/step_<N>/host_<i>.npz  +  <dir>/step_<N>/MANIFEST.json
The manifest is written LAST (atomic rename), so a checkpoint directory is
valid iff the manifest exists — a crash mid-write can never be mistaken for
a complete checkpoint, and restore() simply picks the newest valid step.

Arrays are saved as full logical values (this container is single-host; the
multi-host path shards by leaf hash across hosts — the code paths are the
same, each host just filters its own leaves).  On restore the arrays are
device_put against the CURRENT mesh's shardings, so restoring onto a
different device count / mesh shape (elastic restart) is free — see
runtime/elastic.py and the elasticity test.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree_util.tree_flatten(state)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False, host_id: int = 0,
                 n_hosts: int = 1):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -------------------------------------------------------------
    def save(self, step: int, state: Any, block: bool = True):
        leaves, _ = _flatten(state)
        arrays = [np.asarray(l) for l in leaves]  # pull off device

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}_{self.host_id}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"host_{self.host_id}.npz"),
                     **{f"leaf_{i}": a for i, a in enumerate(arrays)})
            manifest = {"step": step, "n_leaves": len(arrays),
                        "n_hosts": self.n_hosts, "time": time.time()}
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)   # atomic publish
            self._gc()

        if self.async_save:
            self.wait()
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
            if block:
                self.wait()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def list_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "MANIFEST.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of ``like``.  ``shardings`` (optional
        tree of NamedSharding) reshards onto the current mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}",
                            f"host_{self.host_id}.npz")
        data = np.load(path)
        leaves, treedef = _flatten(like)
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: isinstance(
                    x, jax.sharding.Sharding))
        else:
            sh_leaves = [None] * len(leaves)
        out = []
        for i, (l, sh) in enumerate(zip(leaves, sh_leaves)):
            arr = data[f"leaf_{i}"]
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr, dtype=l.dtype))
        return jax.tree_util.tree_unflatten(treedef, out), step
