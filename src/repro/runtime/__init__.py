from .checkpoint import Checkpointer
from .elastic import mesh_shardings, reshard_state
from .failures import RetryConfig, run_with_retries

__all__ = ["Checkpointer", "mesh_shardings", "reshard_state", "RetryConfig",
           "run_with_retries"]
