from .checkpoint import Checkpointer
from .elastic import reshard_state
from .failures import RetryConfig, run_with_retries

__all__ = ["Checkpointer", "reshard_state", "RetryConfig",
           "run_with_retries"]
