"""Serving steps: prefill (build caches, return last logits) and decode
(one token against the cache).  Covers decoder LMs, the VLM (visual prefix
in the cache) and the enc-dec model (encoder memory + cross-KV precompute).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.encdec import (decode_forward, encode, init_encdec_caches,
                                 precompute_cross_kv)
from repro.models.lm import init_caches, lm_forward
from repro.nn.common import no_shard


def make_prefill_step(arch: ArchConfig, batch: int, max_len: int,
                      shard=no_shard, cache_dtype=jnp.bfloat16,
                      cache_constraint=None):
    """``cache_constraint`` (optional): pytree hook that pins the freshly
    created cache buffers to their serving sharding — without it the
    in-graph zeros can materialize replicated before the layer scan."""
    cc = cache_constraint or (lambda c: c)
    if arch.encdec:
        def prefill(params, batch_inputs):
            frames = batch_inputs["frames"]
            tokens = batch_inputs["tokens"]
            memory = encode(params, frames, arch, shard=shard)
            caches = cc(init_encdec_caches(arch, batch, max_len,
                                           frames.shape[1], cache_dtype))
            cross = precompute_cross_kv(params, memory, arch, shard=shard)
            caches = {"self": caches["self"],
                      "cross": jax.tree_util.tree_map(
                          lambda b, v: v.astype(b.dtype), caches["cross"],
                          cross)}
            out = decode_forward(params, arch, tokens, memory=memory,
                                 caches=caches, shard=shard,
                                 mode="prefill", return_hidden=True)
            # head applied to the LAST position only — never materialize
            # the (B, S, V) prefill logits
            logits = (out["hidden"][:, -1:] @ out["head"]) \
                .astype(jnp.float32)
            return logits, out["caches"]
        return prefill

    def prefill(params, batch_inputs):
        caches = cc(init_caches(arch, batch, max_len, cache_dtype))
        out = lm_forward(params, arch, batch_inputs["tokens"],
                         caches=caches,
                         extra_embeds=batch_inputs.get("patch_embeds"),
                         shard=shard, mode="prefill", return_hidden=True)
        logits = (out["hidden"][:, -1:] @ out["head"]).astype(jnp.float32)
        return logits, out["caches"]
    return prefill


def make_decode_step(arch: ArchConfig, shard=no_shard):
    if arch.encdec:
        def decode(params, caches, token, pos):
            out = decode_forward(params, arch, token, caches=caches,
                                 pos=pos, shard=shard, mode="decode")
            return out["logits"], out["caches"]
        return decode

    def decode(params, caches, token, pos):
        out = lm_forward(params, arch, token, caches=caches, pos=pos,
                         shard=shard, mode="decode")
        return out["logits"], out["caches"]
    return decode
