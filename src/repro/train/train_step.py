"""Training step factory: loss -> grad (any mode) -> compress -> clip ->
AdamW, with optional microbatch gradient accumulation.

One factory serves every assigned architecture: decoder LMs (dense / MoE /
SSM / hybrid), the VLM (patch-embedding prefix), and the enc-dec audio model.
The gradient scheme is selected by the arch config's NodeConfig.grad_mode —
a registered strategy name or a ``repro.core.GradientStrategy`` instance
(the paper's ``SymplecticAdjoint`` being the headline mode); the LM forward
resolves it through ``repro.core.solve`` (core/api.py), so a newly
registered strategy is trainable here with zero changes to this factory.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.encdec import decode_forward, encode, init_encdec
from repro.models.lm import init_lm, lm_forward
from repro.nn.common import no_shard
from repro.optim import (AdamWConfig, CompressionConfig, adamw_init,
                         adamw_update, clip_by_global_norm, compress_grads,
                         decompress_grads)
from repro.optim.compress import init_error_state
from .losses import IGNORE, lm_loss, lm_loss_chunked
from .state import TrainState, init_solver_stats, node_solver_counts


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    max_grad_norm: float = 1.0
    microbatches: int = 1
    adamw: AdamWConfig = AdamWConfig()
    compression: CompressionConfig = CompressionConfig()
    param_dtype: str = "float32"
    # chunked cross-entropy: never materialize (B, S, V) logits.
    # 0 disables (full-logits path, kept for ablation).
    loss_chunk: int = 512


def init_train_state(key, arch: ArchConfig, tcfg: TrainConfig) -> TrainState:
    """Fresh ``TrainState`` — the full checkpoint contract (see state.py)."""
    init_key, train_key = jax.random.split(key)
    dtype = jnp.dtype(tcfg.param_dtype)
    if arch.encdec:
        params = init_encdec(init_key, arch, dtype)
    else:
        params = init_lm(init_key, arch, dtype)
    return TrainState(
        params=params, opt=adamw_init(params, tcfg.adamw), rng=train_key,
        data_step=jnp.zeros((), jnp.int32),
        solver_stats=init_solver_stats(),
        compress_err=init_error_state(params, tcfg.compression))


def _forward_loss(params, batch, arch: ArchConfig, shard,
                  loss_chunk: int = 512):
    rh = loss_chunk > 0
    if arch.encdec:
        memory = encode(params, batch["frames"], arch, shard=shard)
        out = decode_forward(params, arch, batch["tokens"], memory=memory,
                             shard=shard, mode="train", return_hidden=rh)
        labels = batch["labels"]
    elif arch.frontend == "patch":
        out = lm_forward(params, arch, batch["tokens"],
                         extra_embeds=batch["patch_embeds"], shard=shard,
                         mode="train", return_hidden=rh)
        P = batch["patch_embeds"].shape[1]
        pad = jnp.full(batch["labels"].shape[:1] + (P,), IGNORE,
                       batch["labels"].dtype)
        labels = jnp.concatenate([pad, batch["labels"]], axis=1)
    else:
        out = lm_forward(params, arch, batch["tokens"], shard=shard,
                         mode="train", return_hidden=rh)
        labels = batch["labels"]
    if rh:
        loss = lm_loss_chunked(out["hidden"], out["head"], labels,
                               loss_chunk)
    else:
        loss = lm_loss(out["logits"], labels)
    return loss + out["aux"], loss


def make_train_step(arch: ArchConfig, tcfg: TrainConfig,
                    lr_fn: Optional[Callable] = None, shard=no_shard,
                    grad_constraint: Optional[Callable] = None):
    """``grad_constraint`` (optional): pytree->pytree hook applied to the
    gradients before the optimizer — the launcher passes a ZeRO-2-style
    data-axis sharding constraint here, which turns the DP gradient
    all-reduce into a reduce-scatter and divides gradient residency by the
    DP degree (the optimizer update runs sharded; XLA all-gathers the
    updated params, completing the ZeRO-1 flow)."""
    if lr_fn is None:
        lr_fn = lambda step: jnp.asarray(tcfg.lr, jnp.float32)  # noqa: E731

    def grads_of(params, batch):
        def lf(p):
            return _forward_loss(p, batch, arch, shard, tcfg.loss_chunk)
        (total, ce), grads = jax.value_and_grad(lf, has_aux=True)(params)
        return grads, total, ce

    # static forward-solve cost of one train step (NODE archs; see state.py)
    solve_steps, solve_fevals = node_solver_counts(arch)
    n_solves = max(tcfg.microbatches, 1)

    def train_step(state, batch):
        params = state["params"]
        if tcfg.microbatches > 1:
            def mb(carry, mbatch):
                g_acc, l_acc = carry
                g, total, _ = grads_of(params, mbatch)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                if grad_constraint is not None:
                    # keep the f32 accumulator ZeRO-sharded across steps
                    g_acc = grad_constraint(g_acc)
                return (g_acc, l_acc + total), None

            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((tcfg.microbatches,
                                     x.shape[0] // tcfg.microbatches)
                                    + x.shape[1:]), batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if grad_constraint is not None:
                zeros = grad_constraint(zeros)
            (grads, loss_sum), _ = jax.lax.scan(mb, (zeros, 0.0), mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / tcfg.microbatches, grads)
            loss = loss_sum / tcfg.microbatches
        else:
            grads, loss, _ = grads_of(params, batch)

        # gradient compression across the DP all-reduce boundary
        err = state.get("compress_err")
        comp, new_err = compress_grads(grads, tcfg.compression, err)
        grads = decompress_grads(comp, tcfg.compression)
        if grad_constraint is not None:
            grads = grad_constraint(grads)

        grads, gnorm = clip_by_global_norm(grads, tcfg.max_grad_norm)
        lr = lr_fn(state["opt"]["step"])
        params, opt = adamw_update(params, grads, state["opt"], lr,
                                   tcfg.adamw)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        if isinstance(state, TrainState):
            # advance every contract field: split the rng stream (the step
            # key is reserved for stochastic layers), bump the data cursor,
            # accumulate the static solve counters
            rng, _step_key = jax.random.split(state.rng)
            stats = {
                "n_steps": state.solver_stats["n_steps"]
                + jnp.int32(solve_steps * n_solves),
                "n_fevals": state.solver_stats["n_fevals"]
                + jnp.int32(solve_fevals * n_solves)}
            return TrainState(params=params, opt=opt, rng=rng,
                              data_step=state.data_step + 1,
                              solver_stats=stats,
                              compress_err=new_err), metrics
        new_state = {"params": params, "opt": opt}
        if new_err is not None:
            new_state["compress_err"] = new_err
        return new_state, metrics

    return train_step
