"""Losses: causal-LM cross entropy (f32 accumulation, ignore_index)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

IGNORE = -100


def lm_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """logits (B,S,V) f32; labels (B,S) int32 (IGNORE masked)."""
    mask = (labels != IGNORE)
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


def lm_loss_chunked(hidden: jnp.ndarray, head: jnp.ndarray,
                    labels: jnp.ndarray, chunk: int = 512) -> jnp.ndarray:
    """Cross entropy computed per sequence chunk: the (B, chunk, V) logits
    block is materialized, reduced, and rematerialized in backward — the
    full (B, S, V) float32 logits tensor (the dominant live buffer of
    big-vocab training) never exists.

    hidden: (B, S, d) final normed hidden states; head: (d, V).
    """
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)),
                         constant_values=IGNORE)
        S += pad
    nb = S // chunk
    hc = hidden.reshape(B, nb, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nb, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def one(xi, li):
        logits = (xi @ head).astype(jnp.float32)
        mask = (li != IGNORE)
        safe = jnp.where(mask, li, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None],
                                   axis=-1)[..., 0]
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    def body(carry, xs):
        s, c = carry
        ds, dc = one(*xs)
        return (s + ds, c + dc.astype(jnp.int32)), None

    (nll, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.int32(0)),
                                 (hc, lc))
    return nll / jnp.maximum(cnt, 1)
