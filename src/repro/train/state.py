"""The full-train-state checkpoint contract.

``TrainState`` is ONE registered pytree carrying everything a training run
needs to resume bit-identically after process death:

  * ``params``        — model parameters,
  * ``opt``           — AdamW state (m, v, step = the LR-schedule step,
                        optional f32 master copies),
  * ``rng``           — the training PRNG key, split once per step so any
                        stochastic layer added later rides the same contract,
  * ``data_step``     — the data cursor: the next pipeline step to consume
                        (``TokenPipeline`` is keyed by step, so restoring
                        this resumes the exact sample stream),
  * ``solver_stats``  — cumulative ODE-solve counters (fixed-grid NODE
                        forward solves are static counts, see
                        ``node_solver_counts``),
  * ``compress_err``  — int8 gradient-compression error-feedback residual
                        (``None`` when compression is off; the residual is
                        part of the convergence argument, so it must survive
                        a restart).

The contract is what ``runtime.Checkpointer`` saves/restores and what the
fault-injection harness (tests/test_failures.py) proves: kill the process
anywhere — including mid async save — and the resumed loss curve is
bit-identical to the uninterrupted run.  See docs/training.md.

Mapping-style access (``state["params"]``, ``"compress_err" in state``) is
kept so older dict-state callers (launch/serve.py, tests) read either form.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

_FIELDS = ("params", "opt", "rng", "data_step", "solver_stats",
           "compress_err")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    rng: Any
    data_step: Any                 # int32 scalar: next data step to consume
    solver_stats: Any              # {"n_steps": int32, "n_fevals": int32}
    compress_err: Optional[Any] = None

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return tuple(getattr(self, f) for f in _FIELDS), None

    @classmethod
    def tree_unflatten(cls, _aux, children):
        return cls(*children)

    # -- mapping-style compatibility with the legacy dict state -------------
    def __getitem__(self, key):
        if key not in _FIELDS or (key == "compress_err"
                                  and self.compress_err is None):
            raise KeyError(key)
        return getattr(self, key)

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    def __contains__(self, key):
        return key in _FIELDS and not (key == "compress_err"
                                       and self.compress_err is None)

    def keys(self):
        return tuple(f for f in _FIELDS if f in self)

    def replace(self, **kw) -> "TrainState":
        return dataclasses.replace(self, **kw)


def init_solver_stats() -> dict:
    return {"n_steps": jnp.zeros((), jnp.int32),
            "n_fevals": jnp.zeros((), jnp.int32)}


def node_solver_counts(arch) -> tuple:
    """Static per-forward-solve counts for a fixed-grid NODE arch.

    The paper's fixed-grid drivers take exactly ``n_steps`` steps of
    ``s = len(b)`` stage evaluations each (the embedded error estimate is
    skipped on fixed grids), so the forward solve cost is a static
    property of the config — no instrumentation of the jitted step needed.
    Non-NODE archs solve nothing: (0, 0).
    """
    if arch.node.mode != "node":
        return 0, 0
    from repro.core.tableau import get_tableau
    n_steps = arch.node.n_steps or arch.n_repeats
    return n_steps, n_steps * len(get_tableau(arch.node.method).b)
