from .losses import lm_loss
from .train_step import TrainConfig, make_train_step, init_train_state
from .serve_step import make_prefill_step, make_decode_step

__all__ = ["lm_loss", "TrainConfig", "make_train_step", "init_train_state",
           "make_prefill_step", "make_decode_step"]
