from .losses import lm_loss
from .state import TrainState, node_solver_counts
from .train_step import TrainConfig, make_train_step, init_train_state
from .serve_step import make_prefill_step, make_decode_step

__all__ = ["lm_loss", "TrainConfig", "TrainState", "make_train_step",
           "init_train_state", "node_solver_counts", "make_prefill_step",
           "make_decode_step"]
