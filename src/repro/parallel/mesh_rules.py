"""Logical-axis -> mesh-axis rules and the activation sharding hook.

The production mesh axes are ("pod", "data", "model") (multi-pod) or
("data", "model") (single pod).  Tensor parallelism ("model") stays inside a
pod; data parallelism spans ("pod", "data") so cross-pod traffic is only the
gradient all-reduce (DCN-tolerant), per DESIGN.md §7.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# logical activation axis -> mesh axis (resolved against the live mesh)
LOGICAL_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    # layer-boundary residual stream (the scan carry whose per-layer values
    # are SAVED for backward): sequence-sharded over "model" so activation
    # checkpoints take 1/TP of the memory (Korthikanti-style sequence
    # parallelism; XLA turns the TP all-reduce into reduce-scatter +
    # all-gather, same bytes).
    "seq_carry": "model",
    "kv_seq": "data",        # long-context decode: shard cache sequence
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "embed": None,
    "ffn": "model",
    "experts": None,          # expert weights are TP-sharded on d_ff by
    "vocab": "model",         # default; EP (experts->model) is a config knob
}


def _resolve(axis_entry, mesh):
    if axis_entry is None:
        return None
    if isinstance(axis_entry, tuple):
        live = tuple(a for a in axis_entry if a in mesh.axis_names)
        return live if live else None
    return axis_entry if axis_entry in mesh.axis_names else None


def make_sharder(mesh: Optional[jax.sharding.Mesh], rules=None,
                 overrides: Optional[dict] = None):
    """Returns shard(x, logical_axes) applying with_sharding_constraint.

    ``overrides`` lets a launch site retarget logical axes per shape cell
    (e.g. {"seq": "model"} for sequence-parallel activations, or
    {"batch": None, "kv_seq": "data"} for batch-1 long-context decode).
    """
    if mesh is None:
        return lambda x, axes: x
    rules = dict(rules or LOGICAL_RULES)
    if overrides:
        rules.update(overrides)

    def shard(x, axes):
        if x.ndim != len(axes):
            return x
        entries = []
        for dim, a in zip(x.shape, axes):
            e = _resolve(rules.get(a), mesh)
            if e is not None:
                size = (mesh.shape[e] if isinstance(e, str)
                        else int(np.prod([mesh.shape[n] for n in e])))
                # never constrain a non-divisible dim (XLA would pad or
                # involuntarily rematerialize)
                if dim % size != 0 or dim < size:
                    e = None
            entries.append(e)
        spec = P(*entries)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    return shard
