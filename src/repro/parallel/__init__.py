from .mesh_rules import LOGICAL_RULES, make_sharder
from .shardings import batch_specs, cache_specs, param_specs, state_specs
from .solve import (DATA_AXES, batched_solution_specs, lane_axes, lane_spec,
                    lift_scalar_params, resolve_param_specs, shard_count,
                    sharded_solve_triple, solver_state_specs,
                    with_shard_load_stats)

__all__ = ["LOGICAL_RULES", "make_sharder", "param_specs", "state_specs",
           "batch_specs", "cache_specs", "DATA_AXES", "lane_axes",
           "lane_spec", "lift_scalar_params", "shard_count",
           "batched_solution_specs", "solver_state_specs",
           "resolve_param_specs", "sharded_solve_triple",
           "with_shard_load_stats"]
