from .mesh_rules import LOGICAL_RULES, make_sharder
from .shardings import batch_specs, cache_specs, param_specs, state_specs

__all__ = ["LOGICAL_RULES", "make_sharder", "param_specs", "state_specs",
           "batch_specs", "cache_specs"]
