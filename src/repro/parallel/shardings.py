"""PartitionSpec assignment for params, optimizer state, batches and caches.

Param specs are derived from leaf names (path-based rules), giving megatron-
style tensor parallelism:

  column-parallel (shard OUT dim on "model"): wq wk wv wg wu up in_proj
      x_proj wuk wuv frontend up1 up2 lm_head
  row-parallel    (shard IN dim on "model"):  wo wd down out_proj dt_proj
  embed (vocab, d): vocab on "model"
  MoE expert banks (E, d, f)/(E, f, d): shard f on "model" (TP-in-expert);
      set ``ep=True`` to shard E instead (expert parallelism).
  everything else (norms, gates, biases, scalars, ssm params): replicated.

Optimizer state: same spec as its param; with ``zero1=True`` the f32 m/v/
master leaves are additionally sharded over "data" on the first dimension
that is unsharded and divisible (ZeRO-1).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

COL_NAMES = {"wq", "wk", "wv", "wg", "wu", "up", "in_proj", "x_proj",
             "wuk", "wuv", "frontend", "up1", "up2", "lm_head", "wx",
             "wt_gate", "wt_bias", "fc1", "fc2"}
ROW_NAMES = {"wo", "wd", "down", "out_proj", "dt_proj"}
REPLICATED = {"router", "conv_w", "conv_b", "dt_bias", "A_log", "D", "r",
              "b", "w", "b1", "b2", "wi", "wf", "conv_b"}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def _path_names(path):
    out = []
    for entry in path:
        if hasattr(entry, "key"):
            out.append(str(entry.key))
    return out


def _spec_for(path, leaf, mesh, ep: bool, fsdp: bool = False,
              extra_replicated=frozenset()) -> P:
    name = _leaf_name(path)
    if name in extra_replicated:
        return P(*([None] * np.ndim(leaf)))
    names = _path_names(path)
    shape = np.shape(leaf)
    ndim = len(shape)
    model_ok = "model" in mesh.axis_names
    m = "model" if model_ok else None
    if ndim == 0 or m is None:
        return P()
    msize = mesh.shape["model"]
    in_moe = "moe" in names or name == "shared"
    stacked = names and names[0] in ("unit", "enc_unit", "dec_unit")
    off = 1 if stacked else 0   # leading layer-stack dim from vmap'd init

    def pad(spec_tail):
        entries = [None] * off + list(spec_tail)
        # drop any axis assignment whose dim is not divisible
        for i, e in enumerate(entries):
            if e is not None and (shape[i] % msize != 0
                                  or shape[i] < msize):
                entries[i] = None
        if fsdp and "data" in mesh.axis_names:
            # FSDP: additionally shard one weight dim over "data"; XLA
            # all-gathers per layer inside the scan (weight-gathering
            # FSDP).  Never the layer-stack dim (off..), and only large
            # tensors — small norms/gates stay replicated.
            dsize = mesh.shape["data"]
            nelems = 1
            for s in shape:
                nelems *= s
            if nelems >= (1 << 20):
                for i in range(off, len(entries)):
                    if entries[i] is None and shape[i] % dsize == 0 \
                            and shape[i] >= dsize:
                        entries[i] = "data"
                        break
        return P(*entries)

    eff = ndim - off
    if name == "embed" and eff == 2:
        return pad([m, None])
    if in_moe and eff == 3:          # (E, d, f) or (E, f, d) expert banks
        if ep:
            return pad([m, None, None])
        if name in ("wg", "wu"):
            return pad([None, None, m])
        if name == "wd":
            return pad([None, m, None])
        return pad([None] * 3)
    if name in COL_NAMES and eff >= 2:
        return pad([None] * (eff - 1) + [m])
    if name in ROW_NAMES and eff >= 2:
        return pad([m] + [None] * (eff - 1))
    return pad([None] * (ndim - off))


def param_specs(params, mesh, *, ep: bool = False, fsdp: bool = False,
                extra_replicated=frozenset()):
    """Tree of PartitionSpec matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(path, leaf, mesh, ep, fsdp,
                                     extra_replicated), params)


def _zero1_spec(spec: P, shape, mesh) -> P:
    if "data" not in mesh.axis_names:
        return spec
    dsize = mesh.shape["data"]
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for e in entries:  # FSDP already consumed the data axis
        if e == "data" or (isinstance(e, tuple) and "data" in e):
            return spec
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % dsize == 0 and dim >= dsize:
            entries[i] = "data"
            return P(*entries)
    return spec


def state_specs(state, mesh, *, ep: bool = False, zero1: bool = True,
                fsdp: bool = False):
    """Specs for the full train state — the legacy ``{"params", "opt"}``
    dict or a ``train.TrainState`` (rng / data cursor / solver stats are
    host-scalar-sized and always replicated; the result mirrors the input
    pytree kind so it can be used directly as jit in_shardings)."""
    from repro.train.state import TrainState
    if isinstance(state, TrainState):
        as_dict = {"params": state.params, "opt": state.opt}
        if state.compress_err is not None:
            as_dict["compress_err"] = state.compress_err
        base = state_specs(as_dict, mesh, ep=ep, zero1=zero1, fsdp=fsdp)
        repl = lambda t: jax.tree_util.tree_map(  # noqa: E731
            lambda l: P(*([None] * np.ndim(l))), t)
        return TrainState(
            params=base["params"], opt=base["opt"],
            rng=repl(state.rng), data_step=repl(state.data_step),
            solver_stats=repl(state.solver_stats),
            compress_err=base.get("compress_err"))
    pspecs = param_specs(state["params"], mesh, ep=ep, fsdp=fsdp)
    out = {"params": pspecs}
    opt = {}
    for k in state["opt"]:
        if k == "step":
            opt["step"] = P()
            continue
        base = jax.tree_util.tree_map(lambda s: s, pspecs)
        if zero1:
            base = jax.tree_util.tree_map(
                lambda spec, leaf: _zero1_spec(spec, np.shape(leaf), mesh),
                base, state["opt"][k])
        opt[k] = base
    out["opt"] = opt
    if "compress_err" in state:
        out["compress_err"] = jax.tree_util.tree_map(
            lambda s: s, pspecs)
    return out


def batch_specs(batch, mesh):
    """Shard every batch leaf's leading (batch) dim over (pod, data).

    A batch dim that is not divisible by the FULL dp product falls back to
    the longest divisible prefix of ("pod", "data") — with a warning —
    instead of silently replicating (solve.lane_axes is the single source
    of that rule); only when NO prefix divides does the leaf replicate.
    """
    from .solve import lane_axes

    def spec(leaf):
        nd = np.ndim(leaf)
        if nd == 0:
            return P()
        dp = lane_axes(mesh, int(np.shape(leaf)[0]))
        if not dp:
            return P(*([None] * nd))
        return P(dp, *([None] * (nd - 1)))

    return jax.tree_util.tree_map(spec, batch)


def cache_specs(caches, mesh, *, batch_size: int):
    """KV-cache / SSM-state sharding for serving.

    * batch dim -> (pod, data) when divisible;
    * the cache SEQUENCE dim (the huge one) -> "model": decode attention
      against a sequence-sharded cache lowers to partial-softmax + small
      LSE/value all-reduces — the flash-decoding layout, emitted by SPMD;
    * when batch=1 (long_500k) the sequence takes BOTH ("data","model") (or
      as much as divides), and SSM/mLSTM feature states shard over the
      spare axes instead (they have no sequence dim).
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    msize = mesh.shape.get("model", 1)
    batch_sharded = batch_size % dp_size == 0 and batch_size >= dp_size

    def spec(path, leaf):
        shape = np.shape(leaf)
        nd = len(shape)
        if nd == 0:
            return P()
        entries = [None] * nd
        b_idx = 0 if shape[0] == batch_size else \
            (1 if nd > 1 and shape[1] == batch_size else None)
        if b_idx is None:
            return P(*entries)
        if batch_sharded:
            entries[b_idx] = dp
        rest = list(range(b_idx + 1, nd))
        # "sequence-like" dim: the first big trailing dim (>= 1024)
        seq_idx = next((i for i in rest if shape[i] >= 1024), None)
        if seq_idx is not None:
            if batch_sharded:
                if shape[seq_idx] % msize == 0:
                    entries[seq_idx] = "model"
            else:
                full = dp + ("model",)
                fsize = dp_size * msize
                if shape[seq_idx] % fsize == 0:
                    entries[seq_idx] = full
                elif shape[seq_idx] % msize == 0:
                    entries[seq_idx] = "model"
            return P(*entries)
        # stateful (SSM / mLSTM) leaves: no sequence dim — shard features
        cands = sorted(rest, key=lambda i: -shape[i])
        for i in cands:
            if entries[i] is None and shape[i] % msize == 0 and \
                    shape[i] >= msize:
                entries[i] = "model"
                break
        if not batch_sharded and dp:
            for i in cands:
                if entries[i] is None and shape[i] % dp_size == 0 and \
                        shape[i] >= dp_size:
                    entries[i] = dp
                    break
        return P(*entries)

    return jax.tree_util.tree_map_with_path(spec, caches)
