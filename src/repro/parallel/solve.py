"""Mesh-sharded masked-batch solving: lanes over the data axes.

`solve(..., batch_axis=0, mesh=...)` shards the lane axis of the masked
per-lane adaptive driver (docs/batching.md) over the mesh's data-parallel
axes with ``shard_map``.  The shape of the subsystem:

* Lanes are split contiguously over the longest *divisible prefix* of
  ``("pod", "data")`` present in the mesh (`lane_axes`); each shard runs
  the SAME local program a single-device solve of its lane block would run,
  so per-lane values, stats, grids and h carries are bitwise identical to
  the unsharded solve of that block.
* All per-lane controller state (``SolverState.t/h/rtol/atol/n_*`` and the
  checkpoint buffers) lives shard-local inside the ``shard_map`` body —
  the forward pass contains NO cross-device communication.
* Both exact backward passes (the symplectic Algorithm-2 replay and the
  continuous adjoint) replay each lane's accepted grid shard-locally; the
  only cross-device collectives in the backward jaxpr are the ``psum``s
  that reduce the replicated-input cotangents (one per param leaf, plus
  the structurally-zero time cotangents) over the lane axes.  That
  contract is asserted jaxpr-level by ``repro.analysis``'s
  ``collective-count`` probe (docs/parallel.md).
* ``check_rep=False`` throughout: the adaptive driver is a
  ``lax.while_loop`` and shard_map has no replication rule for it.

The gradient path additionally requires every custom_vjp driver to expose
rank-1 time inputs — see ``repro.core.rk.time_lift``.
"""
from __future__ import annotations

import warnings
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.stepper import BatchedAdaptiveSolution, SolverState

#: Mesh axes a batch's lane dim may shard over, in precedence order.
DATA_AXES: Tuple[str, ...] = ("pod", "data")


def lane_axes(mesh, batch: int, axes: Sequence[str] = DATA_AXES, *,
              require: bool = False) -> Tuple[str, ...]:
    """Longest divisible prefix of the data axes for a ``batch``-sized dim.

    Returns the longest prefix of ``axes`` (restricted to axes present in
    ``mesh``) whose total size divides ``batch`` — so a batch that is not
    divisible by the FULL dp product still shards over the axes it can
    fill (e.g. B=6 on a (2, 2) ("pod", "data") mesh shards over "pod"
    alone), instead of silently replicating.  Warns whenever axes are
    dropped; with ``require=True`` an empty result (nothing divides)
    raises instead of degrading to a replicated no-op.
    """
    present = tuple(a for a in axes if a in mesh.shape)
    chosen = present
    while chosen and batch % int(
            np.prod([mesh.shape[a] for a in chosen])) != 0:
        chosen = chosen[:-1]
    if not chosen and require:
        detail = (f"no prefix of its data axes {present} divides the "
                  f"batch dim {batch}" if present
                  else f"mesh axes {tuple(mesh.shape)} contain none of the "
                       f"data axes {tuple(axes)}")
        raise ValueError(
            f"cannot shard the lane axis: {detail}.  Pad the batch or "
            "pick a mesh whose leading data axis divides it")
    if chosen != present:
        full = int(np.prod([mesh.shape[a] for a in present]))
        warnings.warn(
            f"batch dim {batch} is not divisible by the full "
            f"data-parallel product {full} of mesh axes {present}; "
            + (f"sharding over the divisible prefix {chosen} "
               f"(size {int(np.prod([mesh.shape[a] for a in chosen]))})"
               if chosen else "no prefix divides — lanes replicated"),
            stacklevel=2)
    return chosen


def shard_count(mesh, axes: Sequence[str]) -> int:
    """Number of lane shards a mesh realizes over ``axes``."""
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _bcast_spec(tree, spec: P):
    """Broadcast one spec over every leaf of a pytree."""
    return jax.tree_util.tree_map(lambda _: spec, tree)


def lane_spec(axes: Sequence[str], lane_axis: int = 0) -> P:
    """PartitionSpec placing the lane axes at position ``lane_axis``."""
    if not axes:
        return P()
    return P(*([None] * lane_axis), tuple(axes))


def batched_solution_specs(axes: Sequence[str]) -> BatchedAdaptiveSolution:
    """Specs for a ``BatchedAdaptiveSolution``: per-lane leaves on the lane
    axes, step-major checkpoint buffers (max_steps, B, ...) on axis 1."""
    lane = lane_spec(axes)
    step = lane_spec(axes, lane_axis=1)
    return BatchedAdaptiveSolution(
        x_final=lane, xs=step, ts=step, hs=step, n_accepted=lane,
        n_fevals=lane, succeeded=lane, h_final=lane, n_attempts=lane)


def solver_state_specs(state: SolverState, axes: Sequence[str]
                       ) -> SolverState:
    """Specs for a ``SolverState`` (the serve engine's resident state):
    per-lane controller fields on the lane axes, step-major checkpoint
    buffers on axis 1.  Shape-aware — a lane-batched state has (B,)
    horizons (per-lane t0/t1: the engine's heterogeneous requests) while a
    single state's scalar fields replicate."""
    lane = lane_spec(axes)
    step = lane_spec(axes, lane_axis=1)

    def per_lane(leaf):
        return P() if jnp.ndim(leaf) == 0 else lane

    def per_step(leaf):
        # (max_steps,) buffers of an unbatched state have no lane axis
        return step if jnp.ndim(leaf) >= 2 else P()

    return SolverState(
        t0=per_lane(state.t0), t1=per_lane(state.t1), t=per_lane(state.t),
        x=jax.tree_util.tree_map(per_lane, state.x), h=per_lane(state.h),
        n_accepted=per_lane(state.n_accepted),
        n_attempts=per_lane(state.n_attempts),
        n_fevals=per_lane(state.n_fevals),
        xs=jax.tree_util.tree_map(per_step, state.xs),
        ts=per_step(state.ts), hs=per_step(state.hs),
        rtol=None if state.rtol is None else per_lane(state.rtol),
        atol=None if state.atol is None else per_lane(state.atol))


def lift_scalar_params(params):
    """Reshape rank-0 param leaves to ``(1,)`` for the shard_map boundary.

    jax 0.4.37's shard_map transpose mishandles rank-0 differentiable
    inputs (the same ``_SpecError`` the rank-1 time refactor in
    ``repro.core.rk.time_lift`` works around), so a scalar param leaf —
    e.g. a global gain — would break ``grad`` of a sharded solve.  Returns
    ``(lifted, restore, has_scalar)``: the lifted tree crosses the
    shard_map boundary, ``restore`` undoes the lift inside the body, and
    ``has_scalar=False`` means both are identities (no jaxpr change for
    the common all-array case).  The cotangent psum for a lifted leaf has
    operand shape ``(1,)`` rather than ``()``.
    """
    leaves, treedef = jax.tree_util.tree_flatten(params)
    scalar = tuple(jnp.ndim(l) == 0 for l in leaves)
    if not any(scalar):
        return params, (lambda p: p), False
    lifted = treedef.unflatten(
        [jnp.reshape(l, (1,)) if s else l for l, s in zip(leaves, scalar)])

    def restore(params_):
        ls = treedef.flatten_up_to(params_)
        return treedef.unflatten(
            [jnp.reshape(l, ()) if s else l for l, s in zip(ls, scalar)])

    return lifted, restore, True


def resolve_param_specs(params, mesh, sharding):
    """The params in_spec for a sharded solve.

    ``None`` replicates (the default, and the only layout under which the
    shard-local replay is collective-free); ``"auto"`` applies the
    ``shardings.param_specs`` path rules (on a data-only mesh these resolve
    to replication — the wiring exists for meshes that add a model axis);
    anything else is taken as an explicit spec pytree (or prefix) matching
    ``params``.
    """
    if sharding is None:
        return P()
    if sharding == "auto":
        from .shardings import param_specs
        return param_specs(params, mesh)
    return sharding


def sharded_solve_triple(body, mesh, axes: Sequence[str], x0, params, *,
                         params_spec=None, ys_lane_axis: int = 0):
    """shard_map a local ``(ys, stats, success)`` solve body over lanes.

    ``body(x0_local, params)`` must be the LOCAL solve — exactly what a
    single-device call would run on one shard's lane block.  ``x0`` leaves
    shard on axis 0; ``ys`` leaves shard on ``ys_lane_axis`` (0 for t1
    output, 1 for time-major SaveAt stacks); stats and success are per-lane
    and shard on axis 0.
    """
    lane = lane_spec(axes)
    pspec = P() if params_spec is None else params_spec
    return shard_map(
        body, mesh=mesh,
        in_specs=(lane, pspec),
        out_specs=(lane_spec(axes, ys_lane_axis), lane, lane),
        check_rep=False)(x0, params)


def with_shard_load_stats(stats: dict, n_shards: int) -> dict:
    """Attach the cross-shard load-imbalance metric to a solve's stats.

    ``shard_steps`` is each shard's total accepted-step count (lanes are
    contiguous blocks, so a reshape-sum over the gathered per-lane counts
    recovers the per-shard totals without any collective); the adaptive
    while-loop runs until the SLOWEST lane of each shard finishes, so
    ``load_imbalance`` = max/mean of ``shard_steps`` approximates the
    wall-clock cost of heterogeneous stiffness across shards (1.0 =
    perfectly balanced).
    """
    shard_steps = jnp.sum(
        jnp.reshape(stats["n_steps"], (n_shards, -1)), axis=1)
    ftype = jnp.result_type(float)
    mean = jnp.mean(shard_steps.astype(ftype))
    imbalance = jnp.where(mean > 0,
                          jnp.max(shard_steps).astype(ftype) / mean,
                          jnp.ones((), ftype))
    out = dict(stats)
    out["shard_steps"] = shard_steps
    out["load_imbalance"] = imbalance
    return out
