"""LR schedules: cosine, constant, and WSD (Warmup-Stable-Decay, MiniCPM)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    def fn(step):
        return jnp.asarray(lr, jnp.float32)
    return fn


def cosine_schedule(lr: float, warmup: int, total: int,
                    final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * \
            0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return fn


def wsd_schedule(lr: float, warmup: int, stable: int, decay: int,
                 final_frac: float = 0.01):
    """MiniCPM's Warmup-Stable-Decay: linear warmup, flat plateau, then
    exponential-style decay over ``decay`` steps."""
    def fn(step):
        s = step.astype(jnp.float32)
        warm = lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = lr * (final_frac ** prog)
        return jnp.where(s < warmup, warm,
                         jnp.where(s < warmup + stable, lr, dec))
    return fn
