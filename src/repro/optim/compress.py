"""Gradient compression for the data-parallel all-reduce.

Two modes, applied to the gradient pytree BEFORE the optimizer (i.e. before
the pjit-inserted DP all-reduce in the real deployment; on the roofline this
halves/quarters the dominant cross-pod collective bytes):

  * "bf16": cast grads to bfloat16 (2x reduction, no state).
  * "int8": per-tensor symmetric int8 quantization with error feedback —
    the residual is carried in the optimizer state and re-added next step,
    preserving convergence (1-bit-Adam-style argument).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    mode: str = "none"           # none | bf16 | int8
    error_feedback: bool = True


def init_error_state(params, cfg: CompressionConfig):
    if cfg.mode == "int8" and cfg.error_feedback:
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return None


def compress_grads(grads, cfg: CompressionConfig, error_state=None):
    """Returns (compressed_repr, new_error_state)."""
    if cfg.mode == "none":
        return grads, error_state
    if cfg.mode == "bf16":
        return jax.tree_util.tree_map(
            lambda g: g.astype(jnp.bfloat16), grads), error_state
    if cfg.mode == "int8":
        def q(g, e):
            g32 = g.astype(jnp.float32) + (e if e is not None else 0.0)
            scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
            qi = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
            err = g32 - qi.astype(jnp.float32) * scale
            return (qi, scale), err

        if error_state is None:
            error_state = jax.tree_util.tree_map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads)
        leaves_g, treedef = jax.tree_util.tree_flatten(grads)
        leaves_e = jax.tree_util.tree_leaves(error_state)
        qs, errs = [], []
        for g, e in zip(leaves_g, leaves_e):
            qq, err = q(g, e)
            qs.append(qq)
            errs.append(err)
        return treedef.unflatten(qs), treedef.unflatten(errs)
    raise ValueError(cfg.mode)


def decompress_grads(comp, cfg: CompressionConfig, like=None):
    if cfg.mode == "none":
        return comp
    if cfg.mode == "bf16":
        return jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), comp)
    if cfg.mode == "int8":
        def dq(t):
            qi, scale = t
            return qi.astype(jnp.float32) * scale
        return jax.tree_util.tree_map(
            dq, comp, is_leaf=lambda x: isinstance(x, tuple)
            and len(x) == 2 and hasattr(x[0], "dtype"))
    raise ValueError(cfg.mode)
