from .adamw import adamw_init, adamw_update, AdamWConfig
from .schedules import cosine_schedule, wsd_schedule, constant_schedule
from .clip import clip_by_global_norm
from .compress import compress_grads, decompress_grads, CompressionConfig

__all__ = ["adamw_init", "adamw_update", "AdamWConfig", "cosine_schedule",
           "wsd_schedule", "constant_schedule", "clip_by_global_norm",
           "compress_grads", "decompress_grads", "CompressionConfig"]
