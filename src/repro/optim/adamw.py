"""AdamW with mixed-precision master weights.

Optimizer state (per param leaf): m, v in float32, plus a float32 master
copy when params are stored in bf16.  State leaves are annotated for ZeRO-1
sharding by parallel/shardings.py (sharded along the data axis on top of the
param's own tensor-parallel sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    master_f32: bool = True


def adamw_init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    state = {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    # master copies only when params are reduced precision — for f32
    # params p.astype(f32) would ALIAS the param buffer (double-donation
    # crash under donate_argnums) and waste memory
    low_precision = any(l.dtype != jnp.float32
                        for l in jax.tree_util.tree_leaves(params))
    if cfg.master_f32 and low_precision:
        state["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(params, grads, state, lr, cfg: AdamWConfig):
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mh = m / b1c
        vh = v / b2c
        base = master if master is not None else p.astype(jnp.float32)
        new = base - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                           + cfg.weight_decay * base)
        return new.astype(p.dtype), m, v, new

    leaves_p, treedef = jax.tree_util.tree_flatten(params)
    leaves_g = jax.tree_util.tree_leaves(grads)
    leaves_m = jax.tree_util.tree_leaves(state["m"])
    leaves_v = jax.tree_util.tree_leaves(state["v"])
    if "master" in state:
        leaves_w = jax.tree_util.tree_leaves(state["master"])
    else:
        leaves_w = [None] * len(leaves_p)

    np_, nm, nv, nw = [], [], [], []
    for p, g, m, v, w in zip(leaves_p, leaves_g, leaves_m, leaves_v,
                             leaves_w):
        a, b, c, d = upd(p, g, m, v, w)
        np_.append(a)
        nm.append(b)
        nv.append(c)
        nw.append(d)

    unf = treedef.unflatten
    new_state = {"m": unf(nm), "v": unf(nv), "step": step}
    if "master" in state:
        new_state["master"] = unf(nw)
    return unf(np_), new_state
