"""Continuous-batching ODE solve serving (the JetStream slot model).

``SolveEngine`` drives the SAME ``AdaptiveStepper.advance`` as the offline
drivers over a lane-batched masked ``SolverState``: requests are inserted
into free lanes of the RUNNING state at step boundaries, finished lanes are
harvested and freed, and the state grows through AOT-compiled lane buckets
as offered load rises.  See docs/serving.md.
"""
from .engine import (EngineConfig, Request, Result, SolveEngine,
                     naive_sequential_solve, params_from_checkpoint,
                     serve_timed)
from .stream import latency_summary, poisson_arrivals, synthetic_stream

__all__ = [
    "EngineConfig", "Request", "Result", "SolveEngine",
    "naive_sequential_solve", "params_from_checkpoint", "serve_timed",
    "synthetic_stream", "poisson_arrivals", "latency_summary",
]
