"""Synthetic heterogeneous request streams for the serve engine.

Requests vary in everything a real client would vary: initial state,
horizon length (which drives the number of accepted steps), and solve
tolerances — the heterogeneity is the point, because it is exactly what
defeats lockstep offline batching (every trajectory in a fixed batch waits
for the stiffest lane AND the longest horizon) and what the masked slot
model absorbs.  Host-side numpy randomness: streams are data, not traced.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np
import jax.numpy as jnp

from .engine import Request


def synthetic_stream(n_requests: int, dim: int, seed: int = 0,
                     t1_range=(0.5, 2.0),
                     tol_choices: Sequence[tuple] = ((1e-4, 1e-6),
                                                     (1e-5, 1e-7),
                                                     (1e-6, 1e-8)),
                     ) -> List[Request]:
    """A heterogeneous stream of (dim,)-vector requests: unit-ball initial
    states, horizons uniform in ``t1_range``, tolerances drawn from
    ``tol_choices``."""
    rng = np.random.RandomState(seed)
    dtype = jnp.result_type(float)
    reqs = []
    for _ in range(n_requests):
        x0 = rng.randn(dim).astype(np.result_type(dtype))
        x0 = x0 / max(1.0, float(np.linalg.norm(x0)))
        t1 = float(rng.uniform(*t1_range))
        rtol, atol = tol_choices[rng.randint(len(tol_choices))]
        reqs.append(Request(x0=jnp.asarray(x0, dtype), t0=0.0, t1=t1,
                            rtol=float(rtol), atol=float(atol)))
    return reqs


def poisson_arrivals(n_requests: int, rate_per_s: float,
                     seed: int = 0) -> np.ndarray:
    """Cumulative arrival times (seconds) of a Poisson stream at
    ``rate_per_s`` — the offered-load axis of the serve benchmark."""
    rng = np.random.RandomState(seed + 1)
    gaps = rng.exponential(1.0 / rate_per_s, size=n_requests)
    return np.cumsum(gaps)


def latency_summary(results) -> dict:
    """p50/p99/mean serving latency (ms) over a {rid: Result} map — latency
    is completion minus submission, so queue wait counts."""
    lats = np.array([r.completed_at - r.submitted_at
                     for r in results.values()])
    return {"p50_ms": float(np.percentile(lats, 50) * 1e3),
            "p99_ms": float(np.percentile(lats, 99) * 1e3),
            "mean_ms": float(lats.mean() * 1e3)}
