"""Continuous-batching solve engine over a masked ``SolverState``.

The slot model (after JetStream's decode slots): the engine owns ONE
lane-batched ``SolverState`` of B slots and repeatedly applies the SAME
``AdaptiveStepper.advance`` the offline drivers run — AOT-compiled once per
lane bucket, with the state donated on every call so the slot buffers are
updated in place rather than reallocated.  A slot is either OCCUPIED (a
request mid-solve; its lane of the state is live controller state) or FREE
(an inactive lane — ``t0 == t1`` makes ``lanes_active`` False, so
``advance`` passes it through untouched at the cost of one wasted lane of
each fused f evaluation).

Requests are heterogeneous: each carries its own x0, [t0, t1] horizon, and
rtol/atol.  Tolerances ride the state as per-lane ARRAYS
(``SolverState.rtol``/``atol`` — tolerances as data), so one compiled
``advance`` serves every tolerance mix without recompilation, and the
per-leaf cast in ``_error_norm`` keeps each lane's accept/reject decisions
bit-identical to a single-trajectory solve at the same tolerances.

Insertion and eviction happen at step boundaries, against the RUNNING
state: ``_insert`` (jitted, donated) rewrites one lane — clock, state,
fresh h carry, zeroed counters and checkpoint columns — while every other
lane's mid-flight controller state is untouched.  Eviction reads a finished
lane's result off the state and marks the slot free host-side; the lane
itself is already self-masking (done lanes fail ``lanes_active``).

Bucketing: the engine starts at the smallest configured bucket and GROWS
through ``EngineConfig.buckets`` as concurrent demand (occupied + queued)
rises — each bucket's ``advance`` is AOT-compiled at init, so growth at a
step boundary is a pad, not a compile stall.  The engine never shrinks:
compaction would have to move live lanes between slots (and re-land their
checkpoint columns), and a mostly-free large state costs only wasted lane
slots per step, the same masked-lane price the offline batched driver
already pays (docs/batching.md).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core.stepper import AdaptiveConfig, AdaptiveStepper, SolverState
from ..core.rk import rk_solve_adaptive
from ..core.tableau import ButcherTableau

Pytree = Any


class Request(NamedTuple):
    """One trajectory to solve: its own state, horizon, and tolerances."""
    x0: Pytree
    t0: float
    t1: float
    rtol: float
    atol: float


class Result(NamedTuple):
    """Harvested per-request outcome (host-side scalars + the final state)."""
    x_final: Pytree
    succeeded: bool
    n_accepted: int
    n_fevals: int
    n_attempts: int
    submitted_at: float      # perf_counter stamps; latency = completed -
    completed_at: float      # submitted (includes queue wait — serving time)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    buckets: tuple = (4, 8, 16)   # lane counts advance is AOT-compiled for
    check_every: int = 1          # advance calls between eviction sweeps
    mesh: Any = None              # jax.sharding.Mesh: slot state lives
    #                               lane-sharded over the mesh's data axes
    #                               (repro.parallel.solver_state_specs),
    #                               params replicated; every bucket must
    #                               fill whole lane shards.

    def __post_init__(self):
        if not self.buckets or list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError(f"buckets must be strictly increasing, got "
                             f"{self.buckets}")
        if self.check_every < 1:
            raise ValueError("check_every must be >= 1")
        if self.mesh is not None:
            from ..parallel.solve import lane_axes, shard_count
            axes = lane_axes(self.mesh, self.buckets[0], require=True)
            n = shard_count(self.mesh, axes)
            bad = [B for B in self.buckets if B % n]
            if bad:
                raise ValueError(
                    f"EngineConfig.mesh shards lanes {n}-way over axes "
                    f"{axes}, but bucket(s) {bad} are not divisible by {n}:"
                    " every AOT bucket's slot state must fill whole lane "
                    "shards")


def _map_lanes(state: SolverState, f_lane, f_buf) -> SolverState:
    """Apply ``f_lane`` to every lane-axis-0 field and ``f_buf`` to every
    step-major checkpoint buffer (lane axis 1) of a batched state."""
    return SolverState(
        t0=f_lane(state.t0), t1=f_lane(state.t1), t=f_lane(state.t),
        x=jax.tree_util.tree_map(f_lane, state.x), h=f_lane(state.h),
        n_accepted=f_lane(state.n_accepted),
        n_attempts=f_lane(state.n_attempts),
        n_fevals=f_lane(state.n_fevals),
        xs=jax.tree_util.tree_map(f_buf, state.xs),
        ts=f_buf(state.ts), hs=f_buf(state.hs),
        rtol=None if state.rtol is None else f_lane(state.rtol),
        atol=None if state.atol is None else f_lane(state.atol))


def params_from_checkpoint(directory: str, like: Pytree,
                           step: Optional[int] = None, shardings=None):
    """Load the params leaf out of a TRAINING checkpoint (the full
    ``train.TrainState`` contract saved by ``runtime.Checkpointer``).

    ``like`` must be a state with the same pytree structure as what
    training saved — e.g. ``train.init_train_state`` with the training
    arch/config (parameters are overwritten, so the init values don't
    matter).  Returns ``(params, step)``.  This is the train -> serve
    handoff: tests/test_failures.py proves a checkpoint written by
    ``launch.train`` boots serving with the trained weights.
    """
    from ..runtime import Checkpointer
    state, step = Checkpointer(directory).restore(like, step=step,
                                                  shardings=shardings)
    return state["params"], step


class SolveEngine:
    """Continuous-batching adaptive-solve server.

    ``submit`` enqueues requests; ``run`` drives the slot state until the
    queue and every occupied lane drain, returning {request_id: Result}.
    ``step`` exposes one fill -> advance -> evict boundary for tests and
    incremental driving.  All requests must share the template's state
    pytree structure/shapes (one compiled advance per bucket); values,
    horizons, and tolerances are free per request.
    """

    def __init__(self, f, tab: ButcherTableau, cfg: AdaptiveConfig, params,
                 x0_template: Pytree, engine_cfg: EngineConfig = None,
                 combine_backend: str = "auto"):
        self.stepper = AdaptiveStepper(f, tab, cfg, combine_backend)
        self.cfg = cfg
        self.engine_cfg = engine_cfg or EngineConfig()
        mesh = self.engine_cfg.mesh
        self._mesh = mesh
        if mesh is not None:
            from ..parallel.solve import lane_axes
            self._lane_shard_axes = lane_axes(
                mesh, self.engine_cfg.buckets[0], require=True)
            from jax.sharding import NamedSharding, PartitionSpec
            params = jax.device_put(
                params, NamedSharding(mesh, PartitionSpec()))
        self.params = params
        self._template = jax.tree_util.tree_map(
            lambda l: jnp.zeros(jnp.shape(l), jnp.asarray(l).dtype),
            x0_template)
        self._treedef = jax.tree_util.tree_structure(self._template)
        self._queue: deque = deque()
        self._pending_meta: Dict[int, float] = {}
        self._next_rid = 0
        self._steps_total = 0
        self._inserted_while_running = 0
        buckets = tuple(self.engine_cfg.buckets)
        self._buckets = buckets
        self._advance: Dict[int, Any] = {}
        for B in buckets:
            proto = self._blank_state(B)
            self._advance[B] = (
                jax.jit(self.stepper.advance, donate_argnums=0)
                .lower(proto, params).compile())
        self._active_fn = jax.jit(self.stepper.lanes_active)
        self._insert_fn = jax.jit(self._insert, donate_argnums=0)
        self._harvest_fn = jax.jit(self._harvest)
        self._state = self._blank_state(buckets[0])
        self._lane_rid: List[Optional[int]] = [None] * buckets[0]
        self.restored_step: Optional[int] = None

    @classmethod
    def from_checkpoint(cls, f, tab: ButcherTableau, cfg: AdaptiveConfig,
                        directory: str, like: Pytree, x0_template: Pytree,
                        engine_cfg: EngineConfig = None,
                        combine_backend: str = "auto",
                        step: Optional[int] = None) -> "SolveEngine":
        """Boot an engine from a TRAINING checkpoint: the params leaf of
        the ``train.TrainState`` saved by ``launch.train`` becomes the
        field parameters (``like`` supplies the saved pytree structure,
        see ``params_from_checkpoint``)."""
        params, step = params_from_checkpoint(directory, like, step)
        engine = cls(f, tab, cfg, params, x0_template, engine_cfg,
                     combine_backend)
        engine.restored_step = step
        return engine

    # -- slot-state construction / resizing ---------------------------------
    def _blank_state(self, B: int) -> SolverState:
        """All-free state: t0 == t1 == 0 makes every lane inactive, so
        ``advance`` is the identity until something is inserted."""
        x0 = jax.tree_util.tree_map(
            lambda l: jnp.zeros((B,) + jnp.shape(l), l.dtype),
            self._template)
        state = self.stepper.init_state(
            x0, 0.0, 0.0, lanes=B, rtol=self.cfg.rtol, atol=self.cfg.atol)
        # Donation requires every leaf to own a DISTINCT buffer: eagerly
        # constructed equal constants (t0/t, the zeroed counters) can come
        # back aliased out of jax's constant handling, and donating the
        # same buffer twice is an Execute()-time error.  One explicit copy
        # per leaf at construction breaks the aliases; the advance/insert
        # executables keep them distinct from then on (donated pass-through
        # outputs alias their own distinct inputs).
        return self._commit(
            jax.tree_util.tree_map(lambda l: l.copy(), state))

    def _commit(self, state: SolverState) -> SolverState:
        """Land a slot state on its home layout: lane-sharded over the
        config mesh's data axes when one is set (docs/parallel.md), the
        identity otherwise.  Called wherever a state is (re)built outside
        the compiled path — construction, growth, post-insert — so the
        AOT-compiled ``advance`` always sees the shardings it was lowered
        for."""
        if self._mesh is None:
            return state
        from jax.sharding import NamedSharding, PartitionSpec
        from ..parallel.solve import solver_state_specs
        specs = solver_state_specs(state, self._lane_shard_axes)
        shardings = jax.tree_util.tree_map(
            lambda s: NamedSharding(self._mesh, s), specs,
            is_leaf=lambda s: isinstance(s, PartitionSpec))
        return jax.device_put(state, shardings)

    def _grow(self, new_B: int) -> None:
        B = self._lanes
        blank = self._blank_state(new_B - B)

        def pad0(l, b):
            return jnp.concatenate([l, b], axis=0)

        def pad1(l, b):
            return jnp.concatenate([l, b], axis=1)

        s, b = self._state, blank
        self._state = self._commit(SolverState(
            t0=pad0(s.t0, b.t0), t1=pad0(s.t1, b.t1), t=pad0(s.t, b.t),
            x=jax.tree_util.tree_map(pad0, s.x, b.x), h=pad0(s.h, b.h),
            n_accepted=pad0(s.n_accepted, b.n_accepted),
            n_attempts=pad0(s.n_attempts, b.n_attempts),
            n_fevals=pad0(s.n_fevals, b.n_fevals),
            xs=jax.tree_util.tree_map(pad1, s.xs, b.xs),
            ts=pad1(s.ts, b.ts), hs=pad1(s.hs, b.hs),
            rtol=pad0(s.rtol, b.rtol), atol=pad0(s.atol, b.atol)))
        self._lane_rid.extend([None] * (new_B - B))

    @property
    def _lanes(self) -> int:
        return len(self._lane_rid)

    # -- lane insert / harvest (jitted; lane index is traced data) ----------
    def _insert(self, state: SolverState, lane, x0, t0, t1, rtol, atol):
        """Rewrite ONE lane of a running state for a fresh request: clock at
        t0, fresh h carry (sign(t1-t0) * initial_step, the same seed a
        single solve with h0=None uses), zeroed counters and checkpoint
        columns.  Every other lane is untouched."""
        dtype = state.t.dtype
        t0 = jnp.asarray(t0, dtype)
        t1 = jnp.asarray(t1, dtype)
        h = jnp.sign(t1 - t0) * jnp.asarray(self.cfg.initial_step, dtype)
        zero = jnp.int32(0)
        return state._replace(
            t0=state.t0.at[lane].set(t0),
            t1=state.t1.at[lane].set(t1),
            t=state.t.at[lane].set(t0),
            x=jax.tree_util.tree_map(
                lambda buf, v: buf.at[lane].set(v.astype(buf.dtype)),
                state.x, x0),
            h=state.h.at[lane].set(h),
            n_accepted=state.n_accepted.at[lane].set(zero),
            n_attempts=state.n_attempts.at[lane].set(zero),
            n_fevals=state.n_fevals.at[lane].set(zero),
            xs=jax.tree_util.tree_map(
                lambda buf: buf.at[:, lane].set(jnp.zeros((), buf.dtype)),
                state.xs),
            ts=state.ts.at[:, lane].set(0.0),
            hs=state.hs.at[:, lane].set(0.0),
            rtol=state.rtol.at[lane].set(jnp.asarray(rtol, dtype)),
            atol=state.atol.at[lane].set(jnp.asarray(atol, dtype)))

    def _harvest(self, state: SolverState, lane):
        return (jax.tree_util.tree_map(lambda l: l[lane], state.x),
                self.stepper.succeeded(state)[lane],
                state.n_accepted[lane], state.n_fevals[lane],
                state.n_attempts[lane])

    # -- public API ---------------------------------------------------------
    def submit(self, request: Request) -> int:
        if jax.tree_util.tree_structure(request.x0) != self._treedef:
            raise ValueError("request x0 pytree structure does not match "
                             "the engine's template")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, request, time.perf_counter()))
        return rid

    @property
    def occupancy(self) -> int:
        return sum(rid is not None for rid in self._lane_rid)

    @property
    def pending(self) -> int:
        return len(self._queue)

    def _fill(self) -> None:
        demand = self.occupancy + len(self._queue)
        target = self._lanes
        for B in self._buckets:
            if B >= min(demand, self._buckets[-1]):
                target = max(self._lanes, B)
                break
        else:
            target = self._buckets[-1]
        if target > self._lanes:
            self._grow(target)
        running = self.occupancy > 0
        for lane in range(self._lanes):
            if not self._queue:
                break
            if self._lane_rid[lane] is not None:
                continue
            rid, req, t_sub = self._queue.popleft()
            self._state = self._commit(self._insert_fn(
                self._state, lane, req.x0, req.t0, req.t1, req.rtol,
                req.atol))
            self._lane_rid[lane] = rid
            self._pending_meta[rid] = t_sub
            if running:
                self._inserted_while_running += 1
            running = True

    def _evict(self, results: Dict[int, Result]) -> None:
        active = jax.device_get(self._active_fn(self._state))
        now = time.perf_counter()
        for lane, rid in enumerate(self._lane_rid):
            if rid is None or active[lane]:
                continue
            x, ok, n_acc, fe, n_try = jax.device_get(
                self._harvest_fn(self._state, lane))
            results[rid] = Result(x, bool(ok), int(n_acc), int(fe),
                                  int(n_try), self._pending_meta.pop(rid),
                                  now)
            self._lane_rid[lane] = None

    def step(self, results: Dict[int, Result]) -> None:
        """One step boundary: fill free lanes, one donated AOT advance over
        the whole slot state, evict finished lanes (every ``check_every``
        boundaries)."""
        self._fill()
        self._state = self._advance[self._lanes](self._state, self.params)
        self._steps_total += 1
        if self._steps_total % self.engine_cfg.check_every == 0:
            self._evict(results)

    def run(self, requests=None) -> Dict[int, Result]:
        """Drain the queue (plus ``requests``, submitted first): returns
        {request_id: Result} once every lane is free again."""
        for r in requests or []:
            self.submit(r)
        results: Dict[int, Result] = {}
        while self._queue or self.occupancy:
            self.step(results)
        self._evict(results)   # catch lanes finished between sweeps
        return results

    @property
    def stats(self) -> Dict[str, int]:
        return {"steps_total": self._steps_total,
                "lanes": self._lanes,
                "inserted_while_running": self._inserted_while_running}


def serve_timed(engine: SolveEngine, requests,
                arrivals=None) -> Dict[int, Result]:
    """Drive ``engine`` over ``requests`` with optional arrival pacing.

    ``arrivals`` is a monotone array of offsets in seconds from the start
    (``poisson_arrivals``): each request is submitted once its arrival time
    has passed, so reported latencies include real queue wait under the
    offered load.  ``arrivals=None`` submits everything up front (drain
    mode — equivalent to ``engine.run(requests)``).
    """
    if arrivals is None:
        return engine.run(requests)
    if len(arrivals) != len(requests):
        raise ValueError("one arrival time per request required")
    results: Dict[int, Result] = {}
    start = time.perf_counter()
    i = 0
    while i < len(requests) or engine.pending or engine.occupancy:
        now = time.perf_counter() - start
        while i < len(requests) and arrivals[i] <= now:
            engine.submit(requests[i])
            i += 1
        if engine.pending or engine.occupancy:
            engine.step(results)
        else:                       # idle: nothing in flight, wait it out
            time.sleep(min(float(arrivals[i]) - now, 0.01))
    return results


def naive_sequential_solve(f, tab, cfg: AdaptiveConfig, params, requests,
                           combine_backend: str = "auto",
                           warmup: bool = True):
    """The no-batching baseline: one jitted single-trajectory solve per
    request, sequentially.  Tolerances are closed into the trace exactly as
    the offline drivers do, so each DISTINCT (rtol, atol) pair costs one
    compile; ``warmup`` (default) runs each solver once untimed first, so
    the reported numbers measure steady-state solving, not compilation.
    Returns (results, per-request wall seconds)."""
    cache: Dict[tuple, Any] = {}

    def solver_for(rtol, atol):
        key = (float(rtol), float(atol))
        if key not in cache:
            c = dataclasses.replace(cfg, rtol=key[0], atol=key[1])
            cache[key] = jax.jit(
                lambda x0, t0, t1, p: rk_solve_adaptive(
                    f, tab, x0, t0, t1, p, c, combine_backend))
        return cache[key]

    if warmup:
        for req in requests:
            sol = solver_for(req.rtol, req.atol)(req.x0, req.t0, req.t1,
                                                 params)
        jax.block_until_ready(sol.x_final)

    results, lat = [], []
    for req in requests:
        solver = solver_for(req.rtol, req.atol)
        t0 = time.perf_counter()
        sol = solver(req.x0, req.t0, req.t1, params)
        jax.block_until_ready(sol.x_final)
        lat.append(time.perf_counter() - t0)
        results.append(sol)
    return results, lat
