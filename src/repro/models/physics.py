"""Continuous-time physical systems (the paper's Table 4 workload, HNN++).

Learn the energy functional H(u) of a 1-D periodic PDE with a neural net
(one conv layer + two FC, as in Matsubara et al. 2020), and evolve

    du/dt = G (dH/du)     with  G = d/dx   (KdV, skew-adjoint)
                               G = d^2/dx^2 (Cahn-Hilliard)

Periodic central differences discretize G.  Training interpolates successive
snapshots: loss = MSE(solve(u_k -> dt).ys, u_{k+1}) — which is exactly the
paper's setting where dopri8 (13 stages) shines and the symplectic adjoint's
O(s) stage-checkpoint advantage is largest.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import AdaptiveConfig, SaveAt, as_gradient
from repro.models.per_sample import model_solve_ys, per_sample_mode
from repro.nn.common import dense_init, split_keys


@dataclasses.dataclass(frozen=True)
class PhysicsConfig:
    grid: int = 64                 # spatial points
    dx: float = 0.5
    channels: int = 16
    hidden: int = 64
    system: str = "kdv"            # "kdv" | "cahn_hilliard"
    method: str = "dopri8"
    # a registered strategy name OR a GradientStrategy instance (core/api.py)
    grad_mode: object = "symplectic"
    combine_backend: str = "auto"  # stage-combine dispatch (core/combine.py)
    n_steps: int = 4
    dt: float = 0.1                # snapshot interval
    adaptive: bool = False         # PI-controlled stepping instead of n_steps
    rtol: float = 1e-6
    atol: float = 1e-8
    max_steps: int = 64            # per snapshot segment
    # per-trajectory adaptive step control (solve(..., batch_axis=0)): each
    # trajectory in the batch keeps its own accepted grid, so one
    # sharp-gradient sample cannot force the whole batch onto its fine
    # grid (adaptive solves only; docs/batching.md).
    per_sample: bool = False


def init_energy_net(key, cfg: PhysicsConfig, dtype=jnp.float32):
    ks = split_keys(key, 3)
    ksize = 3
    return {
        "conv_w": dense_init(ks[0], (ksize, 1, cfg.channels), dtype),
        "conv_b": jnp.zeros((cfg.channels,), dtype),
        "fc1": dense_init(ks[1], (cfg.channels, cfg.hidden), dtype),
        "fc1_b": jnp.zeros((cfg.hidden,), dtype),
        "fc2": dense_init(ks[2], (cfg.hidden, 1), dtype),
    }


def energy(params, u):
    """u: (B, grid) -> scalar energy per sample (B,). Periodic conv."""
    B, G = u.shape
    x = u[..., None]                                  # (B,G,1)
    k = params["conv_w"].shape[0]
    pad = k // 2
    xp = jnp.concatenate([x[:, -pad:], x, x[:, :pad]], axis=1)
    h = sum(xp[:, i:i + G] @ params["conv_w"][i] for i in range(k))
    h = jnp.tanh(h + params["conv_b"])
    h = jnp.tanh(h @ params["fc1"] + params["fc1_b"])
    e = h @ params["fc2"]                             # (B,G,1)
    return jnp.sum(e[..., 0], axis=-1)                # integrate over grid


def _dx_op(v, dx):
    return (jnp.roll(v, -1, axis=-1) - jnp.roll(v, 1, axis=-1)) / (2 * dx)


def _lap_op(v, dx):
    return (jnp.roll(v, -1, axis=-1) - 2 * v + jnp.roll(v, 1, axis=-1)) \
        / (dx * dx)


import functools


@functools.lru_cache(maxsize=None)
def hnn_field(system: str, dx: float):
    """Vector field du/dt = G dH/du (closure keeps system/dx static so the
    params pytree passed through odeint stays purely numeric; lru_cache
    preserves function identity for custom_vjp caching)."""
    def field(u, t, params):
        gradH = jax.grad(lambda uu: jnp.sum(energy(params, uu)))(u) / dx
        if system == "kdv":
            return _dx_op(gradH, dx)
        return _lap_op(gradH, dx)
    return field


def _stepping(cfg: PhysicsConfig):
    if cfg.adaptive:
        return AdaptiveConfig(rtol=cfg.rtol, atol=cfg.atol,
                              max_steps=cfg.max_steps)
    return cfg.n_steps


def predict_next(params, u, cfg: PhysicsConfig):
    """One snapshot interval; u: (B, grid) -> (B, grid).

    With ``cfg.per_sample`` (adaptive only) each trajectory runs under its
    own step controller — ``models/per_sample.py`` wraps the state as
    (B, 1, grid) singleton-batch lanes so the energy net still sees a
    (batch, grid) layout, and ``batch_axis=0`` masks per-lane
    accept/reject.
    """
    return model_solve_ys(hnn_field(cfg.system, cfg.dx), u, params,
                          per_sample=per_sample_mode(cfg),
                          saveat=SaveAt(t1=cfg.dt), method=cfg.method,
                          gradient=as_gradient(cfg.grad_mode),
                          stepping=_stepping(cfg),
                          backend=cfg.combine_backend)


def rollout(params, u0, cfg: PhysicsConfig, horizon: int):
    """Evolve u0 for ``horizon`` snapshot intervals in ONE solve.

    Observation times dt, 2dt, ..., horizon*dt via the SaveAt path: the
    symplectic adjoint checkpoints each inter-snapshot segment and every
    gradient mode sees the identical discrete map as ``horizon`` chained
    ``predict_next`` calls — without re-integrating from t=0 per snapshot.
    The SaveAt drivers scan over the snapshot segments, so trace size and
    compile time are O(1) in ``horizon`` — long production rollouts
    (hundreds of snapshots) compile as fast as short ones
    (tests/test_trace_size.py pins this for the 64-snapshot case).
    With ``cfg.per_sample`` adaptive stepping, each trajectory threads its
    OWN controller across every snapshot boundary (batch_axis=0).
    Returns (horizon, B, grid).
    """
    ts = cfg.dt * jnp.arange(1, horizon + 1)
    return model_solve_ys(hnn_field(cfg.system, cfg.dx), u0, params,
                          per_sample=per_sample_mode(cfg),
                          saveat=SaveAt(ts=ts), method=cfg.method,
                          gradient=as_gradient(cfg.grad_mode),
                          stepping=_stepping(cfg),
                          backend=cfg.combine_backend)


def physics_loss(params, u_k, u_k1, cfg: PhysicsConfig):
    pred = predict_next(params, u_k, cfg)
    return jnp.mean((pred - u_k1) ** 2)


def rollout_loss(params, u_traj, cfg: PhysicsConfig):
    """Multi-snapshot interpolation loss over one trajectory batch.

    ``u_traj``: (K+1, B, grid) consecutive snapshots; the loss compares a
    single K-observation solve from u_traj[0] against snapshots 1..K (the
    multi-observation generalization of the paper's pairwise MSE).
    """
    pred = rollout(params, u_traj[0], cfg, u_traj.shape[0] - 1)
    return jnp.mean((pred - u_traj[1:]) ** 2)
