"""Per-sample adaptive solving, shared by the model workloads.

The model nets (CNF concatsquash, HNN energy net) are written against a
``(batch, ...)`` state layout, so giving every sample its OWN step
controller (``solve(..., batch_axis=0)``, docs/batching.md) wraps each
batch element as a lane holding a singleton batch: ``(B, ...)`` becomes
``(B, 1, ...)``, the net still sees a batch axis per lane under the
driver's per-lane vmap, and the observed ``ys`` drop the singleton axis on
the way out.  This module is the ONE place that wrap/unwrap axis
arithmetic lives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import SaveAt, solve


def per_sample_mode(cfg) -> bool:
    """True when ``cfg`` asks for per-sample lanes AND adaptive stepping —
    on a fixed grid every sample takes the identical grid already, so
    per-sample control changes nothing."""
    return bool(cfg.per_sample and cfg.adaptive)


def model_solve_ys(field, state, params, *, per_sample: bool,
                   saveat: SaveAt, **solve_kw):
    """``solve(...).ys`` with optional per-sample step control.

    ``state`` leaves are ``(B, ...)`` with the model's data batch leading.
    ``per_sample=False`` is a plain (lockstep) solve; ``per_sample=True``
    wraps each element as a ``(B, 1, ...)`` singleton-batch lane, solves
    under ``batch_axis=0``, and removes the singleton axis from ``ys``
    (axis 1 for ``SaveAt(t1=...)``; axis 2, after the leading ``len(ts)``
    axis, for ``SaveAt(ts=...)``).
    """
    if not per_sample:
        return solve(field, state, params, saveat=saveat, **solve_kw).ys
    wrapped = jax.tree_util.tree_map(lambda l: l[:, None], state)
    sol = solve(field, wrapped, params, saveat=saveat, batch_axis=0,
                **solve_kw)
    axis = 1 if saveat.kind == "t1" else 2
    return jax.tree_util.tree_map(lambda l: jnp.squeeze(l, axis=axis),
                                  sol.ys)
