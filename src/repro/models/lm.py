"""Decoder-only LM over an arbitrary layer pattern (dense/MoE/SSM/hybrid).

Depth structure: optional prefix layers (e.g. deepseek's dense first layer)
followed by ``n_repeats`` copies of the repeating ``pattern`` unit, executed
with lax.scan over stacked unit params (fast 512-device compiles).

Training modes:
  * discrete (default): standard residual stack; optional jax.checkpoint
    around each scanned unit (cfg.remat).
  * node_mode (cfg.node.mode == "node"): the paper — depth becomes ODE time,
    f(x, t) = R * (unit_{floor(tR)}(x) - x), integrated by the configured RK
    method with the configured gradient scheme (symplectic adjoint, etc.).
    With method="euler", n_steps=R this reproduces the discrete stack
    EXACTLY (tests assert it), so the paper's memory result applies to the
    unmodified architecture.

Serving: ``mode="prefill"`` fills KV caches / SSM states and returns final
logits; ``mode="decode"`` advances one token at position ``pos``.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import SaveAt, as_gradient, solve
from repro.nn.common import dense_init, embed_init, no_shard, split_keys
from repro.nn.norm import init_rmsnorm, rmsnorm
from .blocks import init_layer, init_layer_cache, layer_forward


@jax.custom_jvp
def _barrier_leaves(leaves):
    return jax.lax.optimization_barrier(leaves)


@_barrier_leaves.defjvp
def _barrier_leaves_jvp(primals, tangents):
    # optimization_barrier has no differentiation rule; the barrier only
    # needs to pin the PRIMAL slices in the loop body, so tangents pass
    # through as the identity (linear, hence reverse-mode transposable).
    (leaves,), (dleaves,) = primals, tangents
    return jax.lax.optimization_barrier(leaves), dleaves


def _loop_barrier(tree):
    """Opaque identity on a scan body's sliced inputs.

    Prevents XLA from rewriting convert(slice(stack, i)) into
    slice(convert(stack), i) — i.e. hoisting dtype conversions of the
    per-layer weight/cache slices out of the loop, which would materialize
    a full-stack f32 copy (observed on the CPU backend, where bf16 dots
    lower via f32 operands)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        return tree
    leaves = _barrier_leaves(leaves)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ArchConfig, dtype=jnp.float32):
    ks = split_keys(key, 6 + len(cfg.prefix))
    R = cfg.n_repeats
    params: dict = {
        "embed": embed_init(ks[0], (cfg.vocab, cfg.d_model), dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab),
                                       dtype)
    if cfg.frontend == "patch":
        params["frontend"] = dense_init(ks[2], (cfg.d_frontend, cfg.d_model),
                                        dtype)
    for i, spec in enumerate(cfg.prefix):
        params[f"prefix_{i}"] = init_layer(ks[6 + i], spec, cfg, dtype)

    def init_unit(k):
        kk = split_keys(k, len(cfg.pattern))
        return tuple(init_layer(kk[i], spec, cfg, dtype)
                     for i, spec in enumerate(cfg.pattern))

    unit_keys = jax.random.split(ks[3], R)
    params["unit"] = jax.vmap(init_unit)(unit_keys)
    return params


def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    R = cfg.n_repeats
    prefix = [init_layer_cache(s, cfg, batch, max_len, dtype)
              for s in cfg.prefix]
    unit_one = tuple(init_layer_cache(s, cfg, batch, max_len, dtype)
                     for s in cfg.pattern)
    unit = jax.tree_util.tree_map(
        lambda l: jnp.zeros((R,) + l.shape, l.dtype), unit_one)
    return {"prefix": prefix, "unit": unit}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _unit_forward(unit_params, x, cfg: ArchConfig, *, caches=None, pos=None,
                  positions=None, shard=no_shard):
    new_caches = []
    aux = jnp.zeros((), jnp.float32)
    # multi-layer units (jamba's 8-layer block, xlstm's 8-block unit) remat
    # each LAYER too, so a unit's backward never co-materializes all its
    # layers' intermediates (nested remat composes with the scan-level one)
    per_layer_remat = cfg.remat and len(cfg.pattern) > 1 and caches is None
    for i, spec in enumerate(cfg.pattern):
        c = None if caches is None else caches[i]

        def run(lp, xx, cc, spec=spec):
            return layer_forward(lp, xx, spec, cfg, cache=cc, pos=pos,
                                 positions=positions, shard=shard)

        if per_layer_remat:
            run = jax.checkpoint(run, static_argnums=())
        x, nc, a = run(unit_params[i], x, c)
        new_caches.append(nc)
        aux = aux + a
    return x, tuple(new_caches), aux


def _embed(params, cfg, tokens, extra_embeds, shard):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.frontend == "patch" and extra_embeds is not None:
        pe = extra_embeds.astype(x.dtype) @ params["frontend"]
        x = jnp.concatenate([pe, x], axis=1)
    return shard(x, ("batch", "seq", "embed"))


def _head_parts(params, cfg, x):
    x = rmsnorm(params["final_norm"], x, eps=cfg.norm_eps,
                use_pallas=cfg.use_pallas)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x, head


def _head(params, cfg, x, shard):
    x, head = _head_parts(params, cfg, x)
    logits = (x @ head).astype(jnp.float32)
    return shard(logits, ("batch", "seq", "vocab"))


def lm_forward(params, cfg: ArchConfig, tokens, *, caches=None, pos=None,
               extra_embeds=None, shard=no_shard, mode: str = "train",
               return_hidden: bool = False):
    """Returns {"logits", "caches", "aux"} — or, with return_hidden=True,
    {"hidden", "head", ...} so the caller can run a chunked loss without
    ever materializing the full (B, S, V) logits.

    mode: "train" (no caches), "prefill" (fill ``caches`` buffers),
    "decode" (tokens (B,1), advance caches at ``pos``)."""

    def finish(xf, caches_out, aux):
        if return_hidden:
            h, head = _head_parts(params, cfg, xf)
            return {"hidden": h, "head": head, "caches": caches_out,
                    "aux": aux}
        return {"logits": _head(params, cfg, xf, shard),
                "caches": caches_out, "aux": aux}

    x = _embed(params, cfg, tokens, extra_embeds, shard)
    S_total = x.shape[1]
    positions = jnp.arange(S_total) if pos is None else None

    if cfg.node.mode == "node" and mode == "train":
        logits_x = _node_depth_solve(params, cfg, x, shard)
        return finish(logits_x, None, jnp.zeros((), jnp.float32))

    aux_total = jnp.zeros((), jnp.float32)
    new_prefix = []
    for i, spec in enumerate(cfg.prefix):
        c = None if caches is None else caches["prefix"][i]
        x, nc, a = layer_forward(params[f"prefix_{i}"], x, spec, cfg,
                                 cache=c, pos=pos, positions=positions,
                                 shard=shard)
        new_prefix.append(nc)
        aux_total = aux_total + a

    unit_caches = None if caches is None else caches["unit"]

    if cfg.scan_unit:
        if unit_caches is None:
            def body_nc(carry, up):
                xc, aux = carry
                up = _loop_barrier(up)
                xc, _, a = _unit_forward(up, xc, cfg, pos=pos,
                                         positions=positions, shard=shard)
                xc = shard(xc, ("batch", "seq_carry", "embed"))
                return (xc, aux + a), None

            if cfg.remat and mode == "train":
                body_nc = jax.checkpoint(body_nc)
            (x, aux_total), _ = jax.lax.scan(body_nc, (x, aux_total),
                                             params["unit"])
            new_unit = None
        else:
            def body(carry, xs):
                xc, aux = carry
                up, uc = _loop_barrier(xs)
                xc, nc, a = _unit_forward(up, xc, cfg, caches=uc, pos=pos,
                                          positions=positions, shard=shard)
                # serving (no backward): carries are not saved, so the
                # seq_carry reshard would only add an all-gather per layer
                xc = shard(xc, ("batch", "seq", "embed"))
                return (xc, aux + a), nc

            if cfg.remat and mode == "train":
                body = jax.checkpoint(body)
            (x, aux_total), new_unit = jax.lax.scan(
                body, (x, aux_total), (params["unit"], unit_caches))
    else:
        R = cfg.n_repeats
        new_unit_list = []
        for r in range(R):
            up = jax.tree_util.tree_map(lambda l: l[r], params["unit"])
            uc = None if unit_caches is None else \
                jax.tree_util.tree_map(lambda l: l[r], unit_caches)
            x, nc, a = _unit_forward(up, x, cfg, caches=uc, pos=pos,
                                     positions=positions, shard=shard)
            aux_total = aux_total + a
            new_unit_list.append(nc)
        new_unit = None if unit_caches is None else \
            jax.tree_util.tree_map(lambda *ls: jnp.stack(ls),
                                   *new_unit_list)

    new_caches = None
    if caches is not None:
        new_caches = {"prefix": new_prefix, "unit": new_unit}
    return finish(x, new_caches, aux_total)


# ---------------------------------------------------------------------------
# node mode: depth-time ODE over the repeat units (the paper's technique)
# ---------------------------------------------------------------------------

def _depth_field(cfg: ArchConfig, shard):
    """f(x, t) = R * (unit_{floor(tR)}(x) - x): depth-time vector field
    shared by the training solve and the depth-observation probe."""
    R = cfg.n_repeats

    def field(xs, t, unit_params):
        n = jnp.clip(jnp.floor(t * R).astype(jnp.int32), 0, R - 1)
        up = jax.tree_util.tree_map(
            lambda l: jax.lax.dynamic_index_in_dim(l, n, 0, keepdims=False),
            unit_params)
        y, _, _ = _unit_forward(up, xs, cfg, shard=shard)
        # the symplectic adjoint SAVES the step states {x_n}; keep them
        # sequence-sharded like the discrete-mode carries
        return shard((y - xs) * float(R), ("batch", "seq_carry", "embed"))

    return field


def _node_depth_solve(params, cfg: ArchConfig, x, shard):
    n_steps = cfg.node.n_steps or cfg.n_repeats
    return solve(_depth_field(cfg, shard), x, params["unit"],
                 saveat=SaveAt(t1=1.0), method=cfg.node.method,
                 gradient=as_gradient(cfg.node.grad_mode),
                 stepping=n_steps,
                 backend=cfg.node.combine_backend).ys


def node_depth_states(params, cfg: ArchConfig, x, depths, shard=no_shard):
    """Observe the depth-time ODE at interior depths (probing/logit-lens).

    ``depths``: monotone observation times in (0, 1] of the depth ODE
    (depth d in [0, n_repeats] corresponds to t = d / n_repeats).  Returns
    hidden states stacked (len(depths), B, S, E) from ONE multi-observation
    solve — the whole depth trajectory costs one forward solve instead of
    one solve per probe depth, and stays differentiable under every
    grad_mode (the symplectic mode checkpoints each inter-depth segment).
    The scanned SaveAt drivers keep trace size and compile time O(1) in
    len(depths), so dense depth sweeps (a probe at every layer of a deep
    stack) compile as fast as a single observation.
    """
    n_steps = cfg.node.n_steps or cfg.n_repeats
    depths = jnp.asarray(depths)
    # per-segment step budget: keep the TOTAL grid comparable to the
    # unobserved solve's n_steps over [0, 1]
    seg_steps = max(1, -(-n_steps // depths.shape[0]))
    return solve(_depth_field(cfg, shard), x, params["unit"],
                 saveat=SaveAt(ts=depths), method=cfg.node.method,
                 gradient=as_gradient(cfg.node.grad_mode),
                 stepping=seg_steps,
                 backend=cfg.node.combine_backend).ys
