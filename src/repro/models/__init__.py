"""Model definitions: decoder LMs (dense/MoE/SSM/hybrid), enc-dec, CNF, HNN."""
