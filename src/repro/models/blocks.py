"""Per-layer block dispatch: init / forward / cache-init for every mixer."""
from __future__ import annotations

from typing import Any, Optional

import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.nn.attention import (gqa_attention, init_gqa, init_gqa_cache,
                                init_mla, init_mla_cache, mla_attention)
from repro.nn.common import no_shard, split_keys
from repro.nn.mamba import init_mamba, init_mamba_state, mamba_forward
from repro.nn.mlp import init_swiglu, swiglu
from repro.nn.moe import init_moe, moe_ffn
from repro.nn.norm import init_rmsnorm, rmsnorm
from repro.nn.xlstm import (init_mlstm, init_mlstm_state, init_slstm,
                            init_slstm_state, mlstm_forward, slstm_forward)


def init_layer(key, spec: LayerSpec, cfg: ArchConfig, dtype=jnp.float32):
    ks = split_keys(key, 4)
    p: dict = {"mixer_norm": init_rmsnorm(cfg.d_model, dtype)}
    if spec.mixer == "attn":
        p["attn"] = init_gqa(ks[0], cfg.attn_config(), dtype)
    elif spec.mixer == "mla":
        p["attn"] = init_mla(ks[0], cfg.attn_config(), dtype)
    elif spec.mixer == "mamba":
        p["mamba"] = init_mamba(ks[0], cfg.mamba_config(), dtype)
    elif spec.mixer == "mlstm":
        p["mlstm"] = init_mlstm(ks[0], cfg.xlstm_config(), dtype)
    elif spec.mixer == "slstm":
        p["slstm"] = init_slstm(ks[0], cfg.xlstm_config(), dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != "none":
        p["ffn_norm"] = init_rmsnorm(cfg.d_model, dtype)
        if spec.ffn == "dense":
            p["mlp"] = init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dtype)
        elif spec.ffn == "moe":
            p["moe"] = init_moe(ks[1], cfg.moe_config(), dtype)
        else:
            raise ValueError(spec.ffn)
    return p


def init_layer_cache(spec: LayerSpec, cfg: ArchConfig, batch: int,
                     max_len: int, dtype=jnp.bfloat16):
    if spec.mixer == "attn":
        return init_gqa_cache(cfg.attn_config(), batch, max_len, dtype)
    if spec.mixer == "mla":
        return init_mla_cache(cfg.attn_config(), batch, max_len, dtype)
    if spec.mixer == "mamba":
        return init_mamba_state(cfg.mamba_config(), batch)
    if spec.mixer == "mlstm":
        return init_mlstm_state(cfg.xlstm_config(), batch)
    if spec.mixer == "slstm":
        return init_slstm_state(cfg.xlstm_config(), batch)
    raise ValueError(spec.mixer)


def layer_forward(p, x, spec: LayerSpec, cfg: ArchConfig, *,
                  cache: Optional[Any] = None, pos=None, positions=None,
                  shard=no_shard, causal: bool = True):
    """Pre-norm residual block: x + mixer(norm(x)) [+ ffn(norm(x))].

    Returns (x, new_cache, aux_loss)."""
    eps = cfg.norm_eps
    up = cfg.use_pallas
    rs = cfg.residual_scale
    h = rmsnorm(p["mixer_norm"], x, eps=eps, use_pallas=up)
    if spec.mixer == "attn":
        y, new_cache = gqa_attention(p["attn"], h, cfg.attn_config(),
                                     positions=positions, cache=cache,
                                     pos=pos, shard=shard, use_pallas=up,
                                     causal=causal)
    elif spec.mixer == "mla":
        y, new_cache = mla_attention(p["attn"], h, cfg.attn_config(),
                                     positions=positions, cache=cache,
                                     pos=pos, shard=shard, use_pallas=up)
    elif spec.mixer == "mamba":
        y, new_cache = mamba_forward(p["mamba"], h, cfg.mamba_config(),
                                     state=cache, shard=shard)
    elif spec.mixer == "mlstm":
        y, new_cache = mlstm_forward(p["mlstm"], h, cfg.xlstm_config(),
                                     state=cache, shard=shard)
    elif spec.mixer == "slstm":
        y, new_cache = slstm_forward(p["slstm"], h, cfg.xlstm_config(),
                                     state=cache, shard=shard)
    else:
        raise ValueError(spec.mixer)
    x = x + rs * y
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn != "none":
        h = rmsnorm(p["ffn_norm"], x, eps=eps, use_pallas=up)
        if spec.ffn == "dense":
            y = swiglu(p["mlp"], h, shard=shard)
        else:
            y, aux = moe_ffn(p["moe"], h, cfg.moe_config(), shard=shard)
        x = x + rs * y
    return x, new_cache, aux
