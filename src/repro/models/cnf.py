"""Continuous normalizing flow (FFJORD) — the paper's Table 2 workload.

M stacked neural-ODE components; each integrates the augmented state
(x, logp_delta, eps) where d(logp_delta)/dt = -Tr(df/dx), estimated by the
Hutchinson estimator eps^T (df/dx) eps (eps fixed per solve, carried in the
state with zero dynamics so every gradient mode — including the symplectic
adjoint — sees a plain augmented ODE).  ``trace="exact"`` uses the exact
jacobian trace for small dims (tests/benchmarks).

Dynamics network: concatsquash MLP (FFJORD's layer: W x * sigmoid(gate(t))
+ bias(t)), tanh nonlinearities.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import AdaptiveConfig, SaveAt, as_gradient
from repro.models.per_sample import model_solve_ys, per_sample_mode
from repro.nn.common import dense_init, split_keys


@dataclasses.dataclass(frozen=True)
class CNFConfig:
    dim: int
    hidden: Tuple[int, ...] = (64, 64)
    n_components: int = 1            # M in the paper
    t1: float = 1.0
    trace: str = "hutchinson"        # "hutchinson" | "exact"
    method: str = "dopri5"
    # a registered strategy name OR a GradientStrategy instance (core/api.py)
    grad_mode: Any = "symplectic"
    combine_backend: str = "auto"    # stage-combine dispatch (core/combine.py)
    n_steps: int = 16
    adaptive: bool = False
    rtol: float = 1e-6
    atol: float = 1e-8
    max_steps: int = 64
    # per-sample adaptive step control (solve(..., batch_axis=0)): each data
    # point gets its own accepted grid, error norm, and accept/reject, so
    # one hard sample no longer drags the whole batch's f-eval count — and
    # the per-sample likelihood stays tolerance-controlled sample-by-sample
    # instead of batch-averaged (docs/batching.md).  Adaptive solves only.
    per_sample: bool = False


def init_cnf(key, cfg: CNFConfig, dtype=jnp.float32):
    """Component params are STACKED: every leaf carries a leading
    ``n_components`` axis, so the component loops in ``cnf_forward`` /
    ``cnf_flow_path`` are single ``lax.scan``s (trace size O(1) in M)."""
    def init_net(k):
        dims = (cfg.dim,) + cfg.hidden + (cfg.dim,)
        layers = []
        for i in range(len(dims) - 1):
            kk = split_keys(k, 3)
            layers.append({
                "w": dense_init(kk[0], (dims[i], dims[i + 1]), dtype),
                "b": jnp.zeros((dims[i + 1],), dtype),
                "wt_gate": dense_init(kk[1], (1, dims[i + 1]), dtype),
                "wt_bias": dense_init(kk[2], (1, dims[i + 1]), dtype),
            })
            k = kk[0]
        return layers

    keys = jax.random.split(key, cfg.n_components)
    return {"components": jax.vmap(init_net)(keys)}


def _dynamics(net, x, t):
    """concatsquash MLP; x: (B, dim) -> (B, dim)."""
    # the time embedding must ride in the STATE dtype: a hardcoded f32
    # here demotes every gate/bias product of an f64 solve under x64
    # (same bug class as the dlp-dtype fix in cnf_forward)
    tt = jnp.reshape(t, (1, 1)).astype(x.dtype)
    h = x
    for i, lp in enumerate(net):
        h = h @ lp["w"] * jax.nn.sigmoid(tt @ lp["wt_gate"]) + \
            lp["b"] + tt @ lp["wt_bias"]
        if i < len(net) - 1:
            h = jnp.tanh(h)
    return h


def _aug_field_hutch(state, t, net):
    x, _, eps = state
    e = jax.lax.stop_gradient(eps)
    fx, vjp_fn = jax.vjp(lambda xx: _dynamics(net, xx, t), x)
    (etJ,) = vjp_fn(e)
    tr_est = jnp.sum(etJ * e, axis=-1)            # eps^T J eps per sample
    return (fx, -tr_est, jnp.zeros_like(eps))


def _aug_field_exact(state, t, net):
    x, _, eps = state

    def f1(xx):
        return _dynamics(net, xx[None], t)[0]

    fx = _dynamics(net, x, t)
    jac = jax.vmap(jax.jacfwd(f1))(x)             # (B, d, d)
    tr = jnp.trace(jac, axis1=-2, axis2=-1)
    return (fx, -tr, jnp.zeros_like(eps))


def cnf_forward(params, u, eps, cfg: CNFConfig):
    """u: (B, dim) data; eps: (B, dim) Hutchinson noise.
    Returns (z, delta_logp) with log p(u) = log N(z) - delta_logp."""
    field = _aug_field_hutch if cfg.trace == "hutchinson" else \
        _aug_field_exact
    # dlp rides in the solve state: it must share u's dtype, or a mixed
    # f64/f32 state corrupts the adaptive error norm and the exact-gradient
    # checks under x64.
    dlp0 = jnp.zeros(u.shape[0], dtype=u.dtype)
    adaptive = AdaptiveConfig(rtol=cfg.rtol, atol=cfg.atol,
                              max_steps=cfg.max_steps) \
        if cfg.adaptive else None
    per_sample = per_sample_mode(cfg)

    def body(carry, comp):
        x, dlp = carry
        x, dlp_i, _ = model_solve_ys(
            field, (x, jnp.zeros_like(dlp), eps), comp,
            per_sample=per_sample,
            saveat=SaveAt(t1=cfg.t1), method=cfg.method,
            gradient=as_gradient(cfg.grad_mode),
            stepping=adaptive if adaptive is not None else cfg.n_steps,
            backend=cfg.combine_backend)
        return (x, dlp + dlp_i), None

    (x, dlp), _ = jax.lax.scan(body, (u, dlp0), params["components"])
    return x, dlp


def cnf_flow_path(params, u, eps, cfg: CNFConfig, ts):
    """Observe the flow (x(t), delta_logp(t)) along the likelihood path.

    ``ts``: observation times within (0, cfg.t1]; ts[-1] should be cfg.t1
    so each component hands its successor the fully transported state (the
    solve ends at ts[-1]).  Returns (xs, dlps) stacked over
    n_components * len(ts) path points: xs[k] is the state after the
    (k // len(ts))-th component has flowed to ts[k % len(ts)], and dlps is
    the CUMULATIVE log-density change up to that point — a single
    multi-observation solve per component instead of len(ts) restarts.

    The component loop is ONE ``lax.scan`` over the stacked component
    params, and each per-component solve is itself a scan over the
    observation segments — so trace size is O(1) in BOTH n_components and
    len(ts), unlocking deep stacks and long likelihood paths at constant
    compile time.
    """
    field = _aug_field_hutch if cfg.trace == "hutchinson" else \
        _aug_field_exact
    ts = jnp.asarray(ts)
    adaptive = AdaptiveConfig(rtol=cfg.rtol, atol=cfg.atol,
                              max_steps=cfg.max_steps) \
        if cfg.adaptive else None
    dlp0 = jnp.zeros(u.shape[0], dtype=u.dtype)   # dtype: see cnf_forward
    per_sample = per_sample_mode(cfg)

    def body(carry, comp):
        x, dlp = carry
        xo, dlpo, _ = model_solve_ys(
            field, (x, jnp.zeros_like(dlp), eps), comp,
            per_sample=per_sample,
            saveat=SaveAt(ts=ts), method=cfg.method,
            gradient=as_gradient(cfg.grad_mode),
            stepping=adaptive if adaptive is not None else cfg.n_steps,
            backend=cfg.combine_backend)
        return (xo[-1], dlp + dlpo[-1]), (xo, dlp[None] + dlpo)

    _, (xs_path, dlp_path) = jax.lax.scan(body, (u, dlp0),
                                          params["components"])
    # (M, len(ts), ...) -> (M * len(ts), ...), matching the old concatenate
    return (xs_path.reshape((-1,) + xs_path.shape[2:]),
            dlp_path.reshape((-1,) + dlp_path.shape[2:]))


def cnf_nll(params, u, eps, cfg: CNFConfig):
    """Mean negative log-likelihood in nats."""
    z, dlp = cnf_forward(params, u, eps, cfg)
    logpz = -0.5 * jnp.sum(z ** 2, -1) - \
        0.5 * cfg.dim * jnp.log(2 * jnp.pi)
    return -jnp.mean(logpz - dlp)
