"""Encoder-decoder transformer (seamless-m4t style, audio frontend stubbed).

Encoder: linear frontend over precomputed fbank-stacked frames
(B, S_enc, d_frontend) -> non-causal self-attention stack.
Decoder: causal self-attention + cross-attention over encoder memory + FFN.

Serving: ``prefill`` encodes the source, precomputes per-layer cross K/V,
fills decoder self-attention caches; ``decode`` advances one target token.
Cache pytree (stacked over decoder layers):
  {"self": {"k","v": (L,B,Smax,H,Dh)}, "cross": {"k","v": (L,B,Senc,H,Dh)}}
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.nn.common import dense_init, embed_init, no_shard, split_keys
from repro.nn.mlp import init_swiglu, swiglu
from repro.nn.norm import init_rmsnorm, rmsnorm
from repro.nn.rope import apply_rope, rope_freqs


def _init_attn(key, d, H, Dh, dtype):
    ks = split_keys(key, 4)
    return {"wq": dense_init(ks[0], (d, H * Dh), dtype),
            "wk": dense_init(ks[1], (d, H * Dh), dtype),
            "wv": dense_init(ks[2], (d, H * Dh), dtype),
            "wo": dense_init(ks[3], (H * Dh, d), dtype)}


def init_encdec(key, cfg: ArchConfig, dtype=jnp.float32):
    ks = split_keys(key, 8)
    d, H, Dh = cfg.d_model, cfg.n_heads, cfg.head_dim

    def init_enc_layer(k):
        kk = split_keys(k, 2)
        return {"attn_norm": init_rmsnorm(d, dtype),
                "attn": _init_attn(kk[0], d, H, Dh, dtype),
                "ffn_norm": init_rmsnorm(d, dtype),
                "mlp": init_swiglu(kk[1], d, cfg.d_ff, dtype)}

    def init_dec_layer(k):
        kk = split_keys(k, 3)
        return {"self_norm": init_rmsnorm(d, dtype),
                "self_attn": _init_attn(kk[0], d, H, Dh, dtype),
                "cross_norm": init_rmsnorm(d, dtype),
                "cross_attn": _init_attn(kk[1], d, H, Dh, dtype),
                "ffn_norm": init_rmsnorm(d, dtype),
                "mlp": init_swiglu(kk[2], d, cfg.d_ff, dtype)}

    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "frontend": dense_init(ks[2], (cfg.d_frontend, d), dtype),
        "enc_unit": jax.vmap(init_enc_layer)(enc_keys),
        "enc_norm": init_rmsnorm(d, dtype),
        "embed": embed_init(ks[3], (cfg.vocab, d), dtype),
        "dec_unit": jax.vmap(init_dec_layer)(dec_keys),
        "dec_norm": init_rmsnorm(d, dtype),
        "lm_head": dense_init(ks[4], (d, cfg.vocab), dtype),
    }


def _mha(p, x, cfg, *, kv=None, causal, positions=None, pos=None,
         cache=None, shard=no_shard):
    """Self-attn when kv is None; cross-attn against kv (B,S_kv,d) else.
    cache (decode self-attn): {"k","v"} updated at pos.
    Returns (out, new_cache)."""
    B, S, d = x.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    inv = rope_freqs(Dh, cfg.rope_theta)
    q = (x @ p["wq"]).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
    new_cache = None
    if kv is None and cache is None:                    # training self-attn
        k = (x @ p["wk"]).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
        v = (x @ p["wv"]).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
        pp = positions if positions is not None else jnp.arange(S)
        if causal:
            q, k = apply_rope(q, pp, inv), apply_rope(k, pp, inv)
        out = kops.attention(q, k, v, causal=causal,
                             use_pallas=cfg.use_pallas)
    elif kv is None:                                    # cached self-attn
        k = (x @ p["wk"]).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
        v = (x @ p["wv"]).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
        if pos is None:  # prefill into buffer
            pp = jnp.arange(S)
            q, k = apply_rope(q, pp, inv), apply_rope(k, pp, inv)
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.transpose(0, 2, 1, 3).astype(cache["k"].dtype),
                (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.transpose(0, 2, 1, 3).astype(cache["v"].dtype),
                (0, 0, 0, 0))
            # pin the per-layer write so the stacked scan output (the
            # serving cache) is built sharded, not replicated
            ck = shard(ck, ("batch", "seq_carry", "cache_heads",
                            "head_dim"))
            cv = shard(cv, ("batch", "seq_carry", "cache_heads",
                            "head_dim"))
            new_cache = {"k": ck, "v": cv}
            out = kops.attention(q, k, v, causal=True,
                                 use_pallas=cfg.use_pallas)
        else:
            ppos = jnp.reshape(pos, (1,))
            q, k = apply_rope(q, ppos, inv), apply_rope(k, ppos, inv)
            z = jnp.zeros((), dtype=jnp.asarray(pos).dtype)
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.transpose(0, 2, 1, 3).astype(cache["k"].dtype),
                (z, pos, z, z))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.transpose(0, 2, 1, 3).astype(cache["v"].dtype),
                (z, pos, z, z))
            new_cache = {"k": ck, "v": cv}
            out = kref.decode_attention_ref(q, ck, cv, pos)
    else:                                               # cross-attn
        if cache is not None and S == 1:                # decode vs memory
            out = kref.decode_attention_ref(
                q, cache["k"], cache["v"], cache["k"].shape[1] - 1)
            new_cache = cache
            out = out.transpose(0, 2, 1, 3).reshape(B, S, H * Dh) @ p["wo"]
            return shard(out, ("batch", "seq", "embed")), new_cache
        if cache is not None:                           # precomputed K/V
            k = cache["k"].transpose(0, 2, 1, 3).astype(q.dtype)
            v = cache["v"].transpose(0, 2, 1, 3).astype(q.dtype)
            new_cache = cache
        else:
            Skv = kv.shape[1]
            k = (kv @ p["wk"]).reshape(B, Skv, H, Dh).transpose(0, 2, 1, 3)
            v = (kv @ p["wv"]).reshape(B, Skv, H, Dh).transpose(0, 2, 1, 3)
        out = kops.attention(q, k, v, causal=False,
                             use_pallas=cfg.use_pallas)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * Dh) @ p["wo"]
    return shard(out, ("batch", "seq", "embed")), new_cache


def encode(params, frames, cfg: ArchConfig, *, shard=no_shard):
    x = frames.astype(params["frontend"].dtype) @ params["frontend"]
    x = shard(x, ("batch", "seq", "embed"))

    def body(xc, lp):
        h = rmsnorm(lp["attn_norm"], xc, eps=cfg.norm_eps)
        y, _ = _mha(lp["attn"], h, cfg, causal=False, shard=shard)
        xc = xc + y
        h = rmsnorm(lp["ffn_norm"], xc, eps=cfg.norm_eps)
        xc = xc + swiglu(lp["mlp"], h, shard=shard)
        return shard(xc, ("batch", "seq_carry", "embed")), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_unit"])
    return rmsnorm(params["enc_norm"], x, eps=cfg.norm_eps)


def precompute_cross_kv(params, memory, cfg: ArchConfig, *,
                        shard=no_shard):
    B, Se, d = memory.shape
    H, Dh = cfg.n_heads, cfg.head_dim

    def body(_, lp):
        k = (memory @ lp["cross_attn"]["wk"]).reshape(B, Se, H, Dh)
        v = (memory @ lp["cross_attn"]["wv"]).reshape(B, Se, H, Dh)
        # cache layout sharding: batch over DP, sequence over model
        k = shard(k, ("batch", "seq_carry", "cache_heads", "head_dim"))
        v = shard(v, ("batch", "seq_carry", "cache_heads", "head_dim"))
        return None, {"k": k, "v": v}

    _, kv = jax.lax.scan(body, None, params["dec_unit"])
    return kv                                            # (L,B,Se,H,Dh)


def decode_forward(params, cfg: ArchConfig, tokens, *, memory=None,
                   caches=None, pos=None, shard=no_shard,
                   mode: str = "train", return_hidden: bool = False):
    """Decoder stack. train: memory given, no caches. prefill: memory +
    cache buffers. decode: caches only (cross K/V inside)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, ("batch", "seq", "embed"))
    positions = jnp.arange(x.shape[1]) if pos is None else None

    def body(xc, xs):
        lp = xs[0]
        self_c = xs[1] if caches is not None else None
        cross_c = xs[2] if caches is not None else None
        h = rmsnorm(lp["self_norm"], xc, eps=cfg.norm_eps)
        y, new_self = _mha(lp["self_attn"], h, cfg, causal=True,
                           positions=positions, pos=pos, cache=self_c,
                           shard=shard)
        xc = xc + y
        h = rmsnorm(lp["cross_norm"], xc, eps=cfg.norm_eps)
        y, _ = _mha(lp["cross_attn"], h, cfg, kv=memory, causal=False,
                    cache=cross_c, shard=shard)
        xc = xc + y
        h = rmsnorm(lp["ffn_norm"], xc, eps=cfg.norm_eps)
        xc = xc + swiglu(lp["mlp"], h, shard=shard)
        carry_axes = ("batch", "seq_carry", "embed") if caches is None \
            else ("batch", "seq", "embed")
        return shard(xc, carry_axes), new_self

    if caches is None:
        def body_nc(xc, lp):
            return body(xc, (lp,))
        fn = jax.checkpoint(body_nc) if mode == "train" else body_nc
        x, _ = jax.lax.scan(fn, x, params["dec_unit"])
        new_caches = None
    else:
        x, new_self = jax.lax.scan(
            body, x, (params["dec_unit"], caches["self"], caches["cross"]))
        new_caches = {"self": new_self, "cross": caches["cross"]}

    x = rmsnorm(params["dec_norm"], x, eps=cfg.norm_eps)
    if return_hidden:
        return {"hidden": x, "head": params["lm_head"],
                "caches": new_caches, "aux": jnp.zeros((), jnp.float32)}
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    return {"logits": shard(logits, ("batch", "seq", "vocab")),
            "caches": new_caches, "aux": jnp.zeros((), jnp.float32)}


def init_encdec_caches(cfg: ArchConfig, batch: int, max_len: int,
                       enc_len: int, dtype=jnp.bfloat16):
    L, H, Dh = cfg.n_layers, cfg.n_heads, cfg.head_dim
    return {
        "self": {"k": jnp.zeros((L, batch, max_len, H, Dh), dtype),
                 "v": jnp.zeros((L, batch, max_len, H, Dh), dtype)},
        "cross": {"k": jnp.zeros((L, batch, enc_len, H, Dh), dtype),
                  "v": jnp.zeros((L, batch, enc_len, H, Dh), dtype)},
    }
