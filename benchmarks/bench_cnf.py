"""Paper Table 2: continuous normalizing flows — NLL / memory / time per
gradient method.

Reduced-scale reproduction: synthetic tabular data at the paper's
dimensionalities, fixed-grid dopri5 (the adaptive path is exercised by
bench_tolerance).  Memory = structural live bytes of one training step;
time = wall clock per iteration on CPU.  Expected ordering (paper Table 2):
  mem:  adjoint ~ symplectic  <<  ACA(remat_step)  <  baseline/backprop
  NLL:  all exact-gradient methods match; adjoint close at tight tol.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.data.tabular import PAPER_DIMS, PAPER_M, make_tabular_dataset
from repro.models.cnf import CNFConfig, cnf_nll, init_cnf
from .common import live_bytes, row, smoke, time_call

MODES = ["backprop", "remat_solve", "remat_step", "adjoint", "symplectic"]
MODE_LABEL = {"backprop": "backprop", "remat_solve": "baseline",
              "remat_step": "ACA", "adjoint": "adjoint",
              "symplectic": "symplectic(ours)"}


def run(dataset: str = "gas", batch: int = 256, steps: int = 60,
        n_steps: int = 8):
    dim = PAPER_DIMS[dataset]
    M = PAPER_M[dataset]
    data = make_tabular_dataset(dataset, n=batch * 4)
    results = {}
    for mode in MODES:
        cfg = CNFConfig(dim=dim, hidden=(64, 64), n_components=M,
                        method="dopri5", grad_mode=mode, n_steps=n_steps)
        params = init_cnf(jax.random.PRNGKey(0), cfg)

        @jax.jit
        def loss_and_grad(params, u, eps):
            return jax.value_and_grad(cnf_nll)(params, u, eps, cfg)

        u = jnp.asarray(data[:batch])
        eps = jax.random.normal(jax.random.PRNGKey(1), u.shape)
        mem = live_bytes(loss_and_grad, params, u, eps)
        t = time_call(lambda p: loss_and_grad(p, u, eps), params, iters=2)

        # short training run for the NLL column
        lr = 1e-3
        p = params
        nll = None
        for i in range(steps):
            ub = jnp.asarray(data[(i * batch) % (3 * batch):
                                  (i * batch) % (3 * batch) + batch])
            ee = jax.random.normal(jax.random.PRNGKey(i), ub.shape)
            nll, g = loss_and_grad(p, ub, ee)
            p = jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)
        results[mode] = dict(mem=mem, t=t, nll=float(nll))
        row(f"cnf_{dataset}_{MODE_LABEL[mode]}", t * 1e6,
            f"mem_mb={mem/2**20:.1f};nll={float(nll):.3f}")
    return results


def main():
    if smoke():
        run("gas", batch=32, steps=2, n_steps=2)
    else:
        run("gas")


if __name__ == "__main__":
    main()
