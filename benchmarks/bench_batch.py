"""Batch-native adaptive solving: masked per-lane control vs lockstep.

Workload: a heterogeneous-stiffness batch of B linear oscillators

    dx/dt = omega_b * [x_1, -x_0],   omega_b log-spaced over ~1.5 decades,

integrated with adaptive dopri5.  The stiffness omega rides in the state
with zero dynamics, so one shared parameter pytree serves every lane.  The
closed-form solution (a rotation by omega_b * t) gives an exact per-lane
accuracy reference.

Three ways to solve the batch:

  * lockstep  — batch-in-state, ``solve(...)`` with no batch_axis: ONE
                controller, error norm pooled (RMS) over the whole batch.
                Every lane takes the same accepted grid, every controller
                f-eval evaluates all B lanes, and the per-lane tolerance is
                NOT enforced — the pooled norm dilutes the stiff lane by
                ~sqrt(B), so its realized error exceeds rtol.
  * masked    — ``solve(..., batch_axis=0)``: per-lane controllers in one
                fused while_loop.  Easy lanes land early and stop paying
                (useful) f-evals; every lane meets its own tolerance.
  * vmap      — ``jax.vmap`` of the single-trajectory solve: semantically
                per-lane too, but JAX's while_loop batching rule selects
                the ENTIRE carry (including the max_steps checkpoint
                buffers) on every trial step — the wall-time gap to the
                masked driver is the cost of those whole-buffer selects.

Reported per row: steady-state wall time, total per-trajectory f-evals
(masked/vmap: sum over lanes of each lane's count; lockstep: B x the shared
controller's count — each of its f-evals evaluates every lane), and the
worst per-lane max-abs error against the closed form.  The acceptance
number is fevals_total: masked needs measurably fewer trajectory-evals
than lockstep on a heterogeneous batch (docs/batching.md quotes the
recorded BENCH_bench_batch.json).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (AdaptiveConfig, DirectBackprop, SaveAt,
                        SymplecticAdjoint, solve)
from .common import row, smoke, time_call

# NOTE: deliberately f32 — run.py executes every bench in one process, so
# flipping jax_enable_x64 here would leak into the other benches (only
# bench_tolerance runs subprocessed).  Tolerances below sit above f32 noise.


def field(state, t, params):
    x, om = state
    dx = params["gain"] * om[..., None] * jnp.stack(
        [x[..., 1], -x[..., 0]], axis=-1)
    return (dx, jnp.zeros_like(om))


PARAMS = {"gain": jnp.float32(1.0)}


def exact(x0, om, t):
    c, s = jnp.cos(om * t), jnp.sin(om * t)
    rot = jnp.stack([jnp.stack([c, s], -1), jnp.stack([-s, c], -1)], -2)
    return jnp.einsum("bij,bj->bi", rot, x0)


def _setup(B, span):
    om = jnp.logspace(0.0, span, B)
    x0 = jax.random.normal(jax.random.PRNGKey(0), (B, 2))
    x0 = x0 / jnp.linalg.norm(x0, axis=-1, keepdims=True)
    return x0, om


def run_one(B, span, t1, cfg):
    x0, om = _setup(B, span)
    state = (x0, om)
    ref = exact(x0, om, t1)

    def err_worst(ys):
        return float(jnp.max(jnp.abs(ys[0] - ref)))

    sv = dict(saveat=SaveAt(t1=t1), method="dopri5",
              gradient=DirectBackprop(), stepping=cfg)

    masked = jax.jit(lambda s: solve(field, s, PARAMS, batch_axis=0, **sv))
    lockstep = jax.jit(lambda s: solve(field, s, PARAMS, **sv))
    vmapped = jax.jit(jax.vmap(lambda s: solve(field, s, PARAMS, **sv)))

    sol_m = masked(state)
    fe_masked = int(jnp.sum(sol_m.stats["n_fevals"]))
    us = time_call(masked, state) * 1e6
    row(f"bench_batch/masked_B{B}", us, f"fevals={fe_masked}",
        B=B, fevals_total=fe_masked,
        fevals_max_lane=int(jnp.max(sol_m.stats["n_fevals"])),
        err_worst=err_worst(sol_m.ys))

    sol_l = lockstep(state)
    # every controller f-eval evaluates the full batch width
    fe_lockstep = B * int(sol_l.stats["n_fevals"])
    us = time_call(lockstep, state) * 1e6
    row(f"bench_batch/lockstep_B{B}", us, f"fevals={fe_lockstep}",
        B=B, fevals_total=fe_lockstep, err_worst=err_worst(sol_l.ys))

    sol_v = vmapped(state)
    fe_vmap = int(jnp.sum(sol_v.stats["n_fevals"]))
    us = time_call(vmapped, state) * 1e6
    row(f"bench_batch/vmap_singles_B{B}", us, f"fevals={fe_vmap}",
        B=B, fevals_total=fe_vmap, err_worst=err_worst(sol_v.ys))

    # symplectic-adjoint gradient: per-lane backward replay vs lockstep
    def loss(s, batch_axis):
        sol = solve(field, s, PARAMS, saveat=SaveAt(t1=t1), method="dopri5",
                    gradient=SymplecticAdjoint(), stepping=cfg,
                    batch_axis=batch_axis)
        return jnp.sum((sol.ys[0] - ref) ** 2)

    for name, ax in (("grad_masked", 0), ("grad_lockstep", None)):
        g = jax.jit(jax.grad(lambda s: loss(s, ax)))
        us = time_call(g, state) * 1e6
        row(f"bench_batch/{name}_B{B}", us, "", B=B)

    print(f"#   B={B}: fevals masked {fe_masked} vs lockstep {fe_lockstep} "
          f"({fe_lockstep / max(fe_masked, 1):.2f}x); worst lane err "
          f"masked {err_worst(sol_m.ys):.2e} vs lockstep "
          f"{err_worst(sol_l.ys):.2e}", flush=True)


def main():
    if smoke():
        cfg = AdaptiveConfig(rtol=1e-5, atol=1e-8, max_steps=256,
                             max_attempts=8192, initial_step=0.05)
        run_one(B=4, span=1.0, t1=1.0, cfg=cfg)
        return
    cfg = AdaptiveConfig(rtol=1e-5, atol=1e-8, max_steps=1024,
                         max_attempts=16384, initial_step=0.05)
    for B in (8, 32):
        run_one(B=B, span=1.5, t1=2.0, cfg=cfg)


if __name__ == "__main__":
    main()
