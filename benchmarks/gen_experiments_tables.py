"""Emit the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from the
dry-run JSONL files."""
from __future__ import annotations

import json
import sys


def load(path):
    rows = {}
    try:
        with open(path) as f:
            for line in f:
                if line.strip():
                    r = json.loads(line)
                    rows[(r["arch"], r["shape"], r.get("mesh"))] = r
    except FileNotFoundError:
        pass
    return rows


def gb(x):
    return f"{x / 2**30:.2f}"


def main():
    sp = load(sys.argv[1] if len(sys.argv) > 1 else "runs/dryrun.jsonl")
    mp = load(sys.argv[2] if len(sys.argv) > 2 else "runs/dryrun_mp.jsonl")

    print("### Dry-run table (single-pod 16x16 = 256 chips; multipod "
          "2x16x16 = 512 chips pass/fail in last column)\n")
    print("| arch | shape | kind | params | hbm GB (tpu-corr) | "
          "flops/dev | coll bytes/dev | AR/AG/RS/A2A (GB) | compile s | "
          "512-chip |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for key in sorted(sp):
        r = sp[key]
        if "error" in r:
            print(f"| {r['arch']} | {r['shape']} | - | - | ERROR | - | - |"
                  f" - | - | - |")
            continue
        c = r["collective_bytes_per_device"]
        mp_r = mp.get(key[:2] + ("2x16x16",))
        mp_ok = "-" if mp_r is None else \
            ("FAIL" if "error" in mp_r else
             f"OK ({mp_r['peak_hbm_gb_tpu']}G)")
        print(f"| {r['arch']} | {r['shape']} | {r['kind']} | "
              f"{r['n_params']/1e9:.2f}B | "
              f"{r['peak_hbm_gb']} ({r.get('peak_hbm_gb_tpu', '?')}) | "
              f"{r['flops_per_device']:.2e} | "
              f"{r['collective_total_bytes']:.2e} | "
              f"{gb(c['all-reduce'])}/{gb(c['all-gather'])}/"
              f"{gb(c['reduce-scatter'])}/{gb(c['all-to-all'])} | "
              f"{r['compile_s']} | {mp_ok} |")

    print("\n### Roofline table (single-pod, per chip per step; "
          "197 TF/s bf16, 819 GB/s HBM, 50 GB/s link)\n")
    print("| arch | shape | compute s | memory s | collective s | "
          "bottleneck | MODEL_FLOPS/HLO | fsdp/mb |")
    print("|---|---|---|---|---|---|---|---|")
    for key in sorted(sp):
        r = sp[key]
        if "error" in r:
            continue
        t = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
              f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
              f"**{t['bottleneck']}** | "
              f"{r.get('useful_flops_ratio', 0) or 0:.3f} | "
              f"{r.get('fsdp', False)}/{r.get('microbatches', 1)} |")

    ok = [r for r in sp.values() if "error" not in r]
    n_mem = sum(1 for r in ok if r["roofline"]["bottleneck"] == "memory")
    n_col = sum(1 for r in ok
                if r["roofline"]["bottleneck"] == "collective")
    print(f"\nSingle-pod cells: {len(ok)} ok / {len(sp)} total; "
          f"bottlenecks: memory={n_mem} collective={n_col} "
          f"compute={len(ok) - n_mem - n_col}")
    mp_ok = [r for r in mp.values() if "error" not in r]
    print(f"Multi-pod cells: {len(mp_ok)} ok / {len(mp)} total")


if __name__ == "__main__":
    main()
