"""Compile-time scaling of the SaveAt drivers in the observation count.

The scan-segmented drivers trace ONE segment body regardless of len(ts),
so jaxpr size and XLA compile time are flat as the observation horizon
grows — this bench measures exactly that, plus the steady-state execution
time, for the symplectic (value + grad) and backprop SaveAt paths.

An ``unrolled`` reference re-implements the pre-scan segmentation (a
Python loop chaining per-segment solves) at SMALL horizons only: its
compile time grows linearly-to-superlinearly in len(ts), which is why the
production horizon (>= 64 observations, the ``scan`` rows) is measured on
the scanned drivers alone — the unrolled form does not fit a CI budget at
that size, and the small-horizon rows give the extrapolation.

CSV: name,compile_time_us,steady_us=...  (BENCH_*.json carries both).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import DirectBackprop, SaveAt, SymplecticAdjoint, solve
from repro.core.rk import rk_solve_fixed
from repro.core.tableau import get_tableau

from .common import row, smoke


def _mlp_field(x, t, params):
    h = jnp.tanh(params["w1"] @ x + params["b1"] + t)
    return params["w2"] @ h + params["b2"]


def _params(dim=8, hidden=16):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    return {
        "w1": jax.random.normal(ks[0], (hidden, dim)) * 0.5,
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(ks[2], (dim, hidden)) * 0.5,
        "b2": jnp.zeros((dim,)),
    }


def _unrolled_saveat(f, x0, params, ts, n_steps):
    """The pre-scan segmentation: Python loop, one traced solve per
    segment (kept ONLY as the compile-time baseline for this bench)."""
    tab = get_tableau("dopri5")
    x, t_prev, obs = x0, jnp.asarray(0.0, ts.dtype), []
    for i in range(ts.shape[0]):
        x = rk_solve_fixed(f, tab, x, t_prev, ts[i], n_steps,
                           params).x_final
        obs.append(x)
        t_prev = ts[i]
    return jnp.stack(obs)


def _measure(build, *args):
    """(compile_seconds, steady_us) of a jitted callable."""
    jitted = jax.jit(build)
    t0 = time.perf_counter()
    compiled = jitted.lower(*args).compile()
    compile_s = time.perf_counter() - t0
    jax.block_until_ready(compiled(*args))
    t0 = time.perf_counter()
    iters = 3
    for _ in range(iters):
        out = compiled(*args)
    jax.block_until_ready(out)
    return compile_s, (time.perf_counter() - t0) / iters * 1e6


def main() -> None:
    n_steps = 2 if smoke() else 4
    horizons = (2, 8) if smoke() else (4, 16, 64)
    unrolled_horizons = (2, 4) if smoke() else (4, 8, 16)
    params = _params()
    x0 = jnp.ones(8)

    def ts_of(n):
        return jnp.linspace(1.0 / n, 1.0, n)

    for n in horizons:
        ts = ts_of(n)

        def value(x0, params, ts=ts):
            return solve(_mlp_field, x0, params, saveat=SaveAt(ts=ts),
                         method="dopri5", gradient=SymplecticAdjoint(),
                         stepping=n_steps).ys

        def loss_grad(x0, params, ts=ts):
            def loss(x0, params):
                return jnp.sum(value(x0, params, ts) ** 2)
            return jax.grad(loss, argnums=(0, 1))(x0, params)

        def value_bp(x0, params, ts=ts):
            return solve(_mlp_field, x0, params, saveat=SaveAt(ts=ts),
                         method="dopri5", gradient=DirectBackprop(),
                         stepping=n_steps).ys

        for label, fn in (("scan_symplectic_value", value),
                          ("scan_symplectic_grad", loss_grad),
                          ("scan_backprop_value", value_bp)):
            c_s, s_us = _measure(fn, x0, params)
            row(f"saveat_compile/{label}/n_obs={n}", c_s * 1e6,
                f"steady_us={s_us:.1f}", compile_s=round(c_s, 4),
                steady_us=round(s_us, 3))

    for n in unrolled_horizons:
        ts = ts_of(n)

        def value_unrolled(x0, params, ts=ts):
            return _unrolled_saveat(_mlp_field, x0, params, ts, n_steps)

        c_s, s_us = _measure(value_unrolled, x0, params)
        row(f"saveat_compile/unrolled_value/n_obs={n}", c_s * 1e6,
            f"steady_us={s_us:.1f}", compile_s=round(c_s, 4),
            steady_us=round(s_us, 3))


if __name__ == "__main__":
    main()
