"""Checkpoint cost: blocking save vs async stall, restore throughput.

The checkpoint contract in numbers (docs/training.md):

  * ``ckpt_save_blocking``    — full synchronous save wall time (host
    transfer + file write + atomic publish);
  * ``ckpt_save_async_stall`` — what the TRAIN LOOP pays for
    ``save(..., block=False)``: the host transfer only, the file write
    runs in a background thread;
  * ``ckpt_overlap``          — a calibrated jitted compute loop timed
    alone vs with a save in flight: the inflation factor is the real cost
    async saving adds to a train step;
  * ``ckpt_restore``          — ``Checkpointer.restore`` throughput in
    MB/s, measured apart from any compute (pure IO + device_put).

Run via ``python -m benchmarks.run [--smoke] bench_checkpoint``; rows land
in BENCH_bench_checkpoint.json (uploaded by the CI bench-smoke lane).
"""
from __future__ import annotations

import shutil
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.runtime import Checkpointer
from . import common


def _synthetic_state(size_mb: int):
    """A params-like pytree of ``size_mb`` 1-MB f32 leaves."""
    leaves = {f"w{i}": jnp.full((256, 1024), float(i + 1), jnp.float32)
              for i in range(size_mb)}
    state = {"params": leaves, "opt": {"step": jnp.int32(7)}}
    jax.block_until_ready(state)
    return state


def main() -> None:
    smoke = common.smoke()
    size_mb = 4 if smoke else 128
    iters = 2 if smoke else 4
    state = _synthetic_state(size_mb)

    d = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        sync = Checkpointer(d, keep=2, async_save=False)
        sync.save(0, state)                       # warm the fs path
        t0 = time.perf_counter()
        for i in range(iters):
            sync.save(i + 1, state)
        t_block = (time.perf_counter() - t0) / iters
        common.row("ckpt_save_blocking", t_block * 1e6,
                   f"{size_mb}MB @ {size_mb / t_block:.0f}MB/s",
                   size_mb=size_mb, mb_per_s=round(size_mb / t_block, 1))

        anc = Checkpointer(d, keep=2, async_save=True)
        stalls = []
        for i in range(iters):
            t0 = time.perf_counter()
            anc.save(100 + i, state, block=False)
            stalls.append(time.perf_counter() - t0)
            anc.wait()
        stall = min(stalls)      # best case = pure host transfer
        common.row("ckpt_save_async_stall", stall * 1e6,
                   f"{stall / t_block * 100:.0f}% of blocking",
                   size_mb=size_mb,
                   stall_vs_blocking=round(stall / t_block, 4))

        # overlap: a compute loop calibrated to roughly one save's worth
        # of work, timed alone vs with a background write in flight
        dim = 256 if smoke else 1024
        x = jnp.ones((dim, dim))
        f = jax.jit(lambda a: jnp.tanh(a @ a) * 0.99)
        f(x).block_until_ready()
        t0 = time.perf_counter()
        f(x).block_until_ready()
        t_call = max(time.perf_counter() - t0, 1e-6)
        n = max(2, int(t_block / t_call))

        def compute():
            t0 = time.perf_counter()
            y = x
            for _ in range(n):
                y = f(y)
            jax.block_until_ready(y)
            return time.perf_counter() - t0

        compute()                                 # warm
        t_alone = compute()
        t0 = time.perf_counter()
        anc.save(200, state, block=False)
        t_with = compute()
        anc.wait()
        wall = time.perf_counter() - t0
        common.row("ckpt_overlap", t_with * 1e6,
                   f"compute inflation x{t_with / t_alone:.2f} "
                   f"({n} calls); save+compute wall {wall * 1e3:.0f}ms",
                   inflation=round(t_with / t_alone, 3),
                   compute_alone_s=round(t_alone, 4),
                   wall_s=round(wall, 4))

        # restore throughput, apart from any compute
        t0 = time.perf_counter()
        for _ in range(iters):
            restored, step = sync.restore(state)
            jax.block_until_ready(restored)
        t_rest = (time.perf_counter() - t0) / iters
        common.row("ckpt_restore", t_rest * 1e6,
                   f"{size_mb}MB @ {size_mb / t_rest:.0f}MB/s",
                   size_mb=size_mb, mb_per_s=round(size_mb / t_rest, 1))
    finally:
        shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    main()
