"""Paper Table 1: verify the structural memory ORDERS, not just points.

Three sweeps, each varying one factor with the others held fixed:
  N (steps), s (stages, via tableau), L (network width as a proxy for
  per-use activation size).  For each gradient mode we report how live
  memory scales — the empirical counterpart of Table 1's big-O column.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cnf import CNFConfig, cnf_nll, init_cnf
from .common import live_bytes, row, smoke

MODES = ["backprop", "remat_step", "adjoint", "symplectic"]


def _mem(mode, method, n_steps, hidden, dim=16, batch=256):
    cfg = CNFConfig(dim=dim, hidden=(hidden, hidden), n_components=1,
                    method=method, grad_mode=mode, n_steps=n_steps)
    params = init_cnf(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(0), (batch, dim))
    eps = jax.random.normal(jax.random.PRNGKey(1), (batch, dim))

    @jax.jit
    def lg(params, u, eps):
        return jax.value_and_grad(cnf_nll)(params, u, eps, cfg)

    return live_bytes(lg, params, u, eps)


def _ratio(xs, ys):
    """Growth ratio when the factor doubles (log-log slope ~ order)."""
    return float(np.polyfit(np.log(xs), np.log(ys), 1)[0])


def run():
    if smoke():
        ns, meths, s_xs, hs = (4, 8), ("heun12", "bosh3"), [2, 4], (16, 32)
        h_fix, kw = 16, dict(dim=4, batch=16)
    else:
        ns, meths, s_xs = (8, 16, 32), ("heun12", "bosh3", "dopri5"), \
            [2, 4, 7]
        hs, h_fix, kw = (64, 128, 256), 128, {}
    out = {}
    for mode in MODES:
        mn = [_mem(mode, "dopri5", n, h_fix, **kw) for n in ns]
        ms = [_mem(mode, meth, 8, h_fix, **kw) for meth in meths]
        ml = [_mem(mode, "dopri5", 8, h, **kw) for h in hs]
        out[mode] = {
            "N_exp": _ratio(list(ns), mn),
            "s_exp": _ratio(s_xs, ms),
            "L_exp": _ratio(list(hs), ml),
        }
        row(f"orders_{mode}", 0.0,
            f"dlogM/dlogN={out[mode]['N_exp']:.2f};"
            f"dlogM/dlogS={out[mode]['s_exp']:.2f};"
            f"dlogM/dlogL={out[mode]['L_exp']:.2f}")
    return out


def main():
    run()


if __name__ == "__main__":
    main()
