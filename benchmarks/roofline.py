"""Roofline table generator: reads the dry-run JSONL and prints per-cell
compute/memory/collective terms + bottleneck (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import json
import os
import sys

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "runs",
                            "dryrun.jsonl")


def load(path):
    cells = {}
    if not os.path.exists(path):
        return cells
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            r = json.loads(line)
            key = (r.get("arch"), r.get("shape"), r.get("mesh"),
                   r.get("node_mode", False), r.get("ep", False),
                   r.get("variant", ""))
            cells[key] = r   # last write wins
    return cells


def fmt_row(r):
    t = r.get("roofline", {})
    return (f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
            f"cmp={t.get('compute_s', 0):9.4f}s "
            f"mem={t.get('memory_s', 0):9.4f}s "
            f"col={t.get('collective_s', 0):9.4f}s "
            f"bot={t.get('bottleneck', '?'):10s} "
            f"hbm={r.get('peak_hbm_gb', -1):7.2f}GB "
            f"useful={r.get('useful_flops_ratio', 0) or 0:6.3f}")


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else DEFAULT_PATH
    cells = load(path)
    ok = [r for r in cells.values() if "error" not in r]
    bad = [r for r in cells.values() if "error" in r]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        print("roofline," + fmt_row(r))
    if bad:
        print(f"roofline,FAILED_CELLS={len(bad)}")
        for r in bad:
            print(f"roofline,FAIL {r['arch']} {r['shape']} {r['mesh']}: "
                  f"{r['error'][:120]}")
    if ok:
        n_mem = sum(1 for r in ok
                    if r["roofline"]["bottleneck"] == "memory")
        n_col = sum(1 for r in ok
                    if r["roofline"]["bottleneck"] == "collective")
        n_cmp = len(ok) - n_mem - n_col
        print(f"roofline,summary cells={len(ok)} memory_bound={n_mem} "
              f"collective_bound={n_col} compute_bound={n_cmp}")


if __name__ == "__main__":
    main()
