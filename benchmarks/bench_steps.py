"""Paper Fig. 2: memory vs number of steps N (fixed dopri5).

Orders (Table 1): backprop O(NsL); ACA O(N + sL); symplectic O(N + s + L);
adjoint O(L).  We sweep N and fit the slope of live bytes in N: backprop's
slope is ~s*L-activations per step, symplectic's is one state vector per
step (the checkpoint), adjoint's is ~0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cnf import CNFConfig, cnf_nll, init_cnf
from .common import live_bytes, row, smoke

MODES = ["backprop", "remat_step", "adjoint", "symplectic"]
MODE_LABEL = {"backprop": "backprop", "remat_step": "ACA",
              "adjoint": "adjoint", "symplectic": "symplectic(ours)"}
NS = [4, 8, 16, 32]


def run(dim: int = 16, batch: int = 512, ns=tuple(NS), hidden: int = 128):
    u = jax.random.normal(jax.random.PRNGKey(0), (batch, dim))
    eps = jax.random.normal(jax.random.PRNGKey(1), (batch, dim))
    out = {}
    for mode in MODES:
        mems = []
        for n in ns:
            cfg = CNFConfig(dim=dim, hidden=(hidden, hidden),
                            n_components=1,
                            method="dopri5", grad_mode=mode, n_steps=n)
            params = init_cnf(jax.random.PRNGKey(0), cfg)

            @jax.jit
            def lg(params, u, eps):
                return jax.value_and_grad(cnf_nll)(params, u, eps, cfg)

            mems.append(live_bytes(lg, params, u, eps))
        slope = np.polyfit(ns, mems, 1)[0]
        out[mode] = dict(mems=mems, slope=slope)
        row(f"steps_{MODE_LABEL[mode]}", 0.0,
            "mem_mb=" + "/".join(f"{m/2**20:.2f}" for m in mems)
            + f";slope_bytes_per_step={slope:.0f}")
    return out


def main():
    if smoke():
        run(dim=4, batch=32, ns=(4, 8), hidden=16)
    else:
        run()


if __name__ == "__main__":
    main()
