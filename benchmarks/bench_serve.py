"""Continuous-batching serve engine vs naive sequential solving.

Workload: a heterogeneous request stream (unit-ball initial states,
horizons uniform in [0.5, 1.0], tolerances drawn from three decades)
against a tanh-MLP field whose weight matrices are the dominant memory
traffic.  That shape is exactly where continuous batching pays on any
backend: a single-trajectory attempt is a chain of GEMVs that re-reads
the full weight set per f-eval, while the engine's lane-batched advance
reads the weights ONCE per 16-lane cohort (a GEMM) — measured ~5x
per-lane amortization on this host — so the slot engine converts memory
bandwidth into throughput the sequential baseline cannot touch.

Three measurements per configuration:

  * naive       — one jitted while_loop solve per request, sequential,
                  caches warmed; steady-state sum of per-solve times.
  * engine drain— everything submitted up front; makespan throughput and
                  serving latency (includes time spent queued for a free
                  lane, so p99 >> p50 is expected at full load).
  * engine @load— Poisson arrivals at a rate ABOVE the naive baseline's
                  throughput: the regime where sequential serving
                  diverges but the engine still clears the queue.

The acceptance number is ``speedup_vs_naive`` on the drain row: the
engine must beat sequential solving end-to-end on the heterogeneous
stream while ``inserted_while_running`` shows requests really joined a
RUNNING batch.  Engine AOT compile time is reported separately
(``engine_init_s``) — it is a server-startup cost, not a per-request one.

NOTE: ``max_steps`` sizes the per-lane checkpoint buffers, and the
engine's step-boundary commit pays for their scatter on every advance
(the offline drivers hide it inside the fused while_loop) — serve
configs should bound max_steps near the real horizon, not leave the
offline default.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AdaptiveConfig
from repro.core.tableau import get_tableau
from repro.serve import (EngineConfig, SolveEngine, latency_summary,
                         naive_sequential_solve, poisson_arrivals,
                         serve_timed, synthetic_stream)
from .common import record, row, smoke

# NOTE: f32 on purpose (run.py shares one process; see bench_batch.py).
# The stream tolerances sit above f32 noise.


def _make(dim, hidden, max_steps, buckets):
    k = jax.random.split(jax.random.PRNGKey(17), 4)
    params = {"w1": jax.random.normal(k[0], (dim, hidden)) * 0.4,
              "b1": jax.random.normal(k[1], (hidden,)) * 0.1,
              "w2": jax.random.normal(k[2], (hidden, dim)) * 0.4,
              "b2": jax.random.normal(k[3], (dim,)) * 0.1}

    def field(x, t, p):
        return jnp.tanh(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]

    cfg = AdaptiveConfig(rtol=1e-4, atol=1e-6, max_steps=max_steps,
                         initial_step=0.02)

    def engine():
        return SolveEngine(field, get_tableau("dopri5"), cfg, params,
                           x0_template=jnp.zeros((dim,)),
                           engine_cfg=EngineConfig(buckets=buckets))
    return field, cfg, params, engine


TOL_CHOICES = ((1e-3, 1e-5), (1e-4, 1e-6), (3e-4, 3e-6))   # above f32 noise


def run_one(dim, hidden, n, max_steps, buckets, load_factors):
    field, cfg, params, make_engine = _make(dim, hidden, max_steps, buckets)
    reqs = synthetic_stream(n, dim, seed=7, t1_range=(0.5, 1.0),
                            tol_choices=TOL_CHOICES)

    # naive baseline: steady state (compiles excluded by internal warmup)
    _, lats = naive_sequential_solve(field, get_tableau("dopri5"), cfg,
                                     params, reqs)
    wall_n = float(np.sum(lats))
    rps_n = n / wall_n
    row(f"bench_serve/naive_sequential_d{dim}", wall_n / n * 1e6,
        f"{rps_n:.1f}req/s", dim=dim, n_requests=n, rps=round(rps_n, 2),
        p50_ms=round(float(np.percentile(lats, 50)) * 1e3, 2),
        p99_ms=round(float(np.percentile(lats, 99)) * 1e3, 2))

    # engine: one throwaway run warms the python paths and XLA caches,
    # then a fresh engine serves the timed run from a clean slot state
    t0 = time.perf_counter()
    eng = make_engine()
    init_s = time.perf_counter() - t0
    eng.run(reqs)
    eng = make_engine()
    t0 = time.perf_counter()
    results = eng.run(reqs)
    wall_e = time.perf_counter() - t0
    assert all(r.succeeded for r in results.values())
    rps_e = n / wall_e
    lat = latency_summary(results)
    speedup = wall_n / wall_e
    row(f"bench_serve/engine_drain_d{dim}", wall_e / n * 1e6,
        f"{rps_e:.1f}req/s {speedup:.2f}x", dim=dim, n_requests=n,
        rps=round(rps_e, 2), speedup_vs_naive=round(speedup, 3),
        p50_ms=round(lat["p50_ms"], 2), p99_ms=round(lat["p99_ms"], 2),
        engine_init_s=round(init_s, 2), **eng.stats)
    print(f"#   d={dim} n={n}: engine {rps_e:.1f} req/s vs naive "
          f"{rps_n:.1f} req/s ({speedup:.2f}x), "
          f"{eng.stats['inserted_while_running']} mid-flight inserts",
          flush=True)

    # offered-load sweep: Poisson arrivals at multiples of the NAIVE
    # baseline's throughput — latency includes queue wait
    for k in load_factors:
        rate = k * rps_n
        eng = make_engine()
        results = serve_timed(eng, reqs,
                              poisson_arrivals(n, rate, seed=7))
        lat = latency_summary(results)
        record(f"bench_serve/engine_load_d{dim}_x{k}",
               offered_rps=round(rate, 2), load_vs_naive=k,
               p50_ms=round(lat["p50_ms"], 2),
               p99_ms=round(lat["p99_ms"], 2),
               ok=all(r.succeeded for r in results.values()),
               **eng.stats)
        print(f"#   d={dim} offered {rate:.1f} req/s ({k}x naive): "
              f"p50 {lat['p50_ms']:.0f} ms p99 {lat['p99_ms']:.0f} ms",
              flush=True)


def main():
    if smoke():
        # rot-check sizes: exercises drain + paced paths, numbers useless
        run_one(dim=8, hidden=16, n=6, max_steps=64, buckets=(2, 4),
                load_factors=(2.0,))
        return
    run_one(dim=1024, hidden=1024, n=20, max_steps=96, buckets=(8, 16),
            load_factors=(0.5, 1.5))


if __name__ == "__main__":
    main()
