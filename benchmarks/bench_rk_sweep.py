"""Paper Table 3: memory/time across Runge-Kutta methods (s = 2,3,6,12).

The paper's key structural claim: the symplectic adjoint's memory is
O(MN + s + L) — nearly FLAT in s — while ACA grows as O(MN + sL) and
backprop as O(MNsL).  We sweep heun12(s=2), bosh3(s=3+fsal),
dopri5(s=6+fsal), dopri8(s=12) at fixed N and report live bytes + time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data.tabular import make_tabular_dataset
from repro.models.cnf import CNFConfig, cnf_nll, init_cnf
from .common import live_bytes, row, smoke, time_call

METHODS = [("heun12", 2), ("bosh3", 3), ("dopri5", 6), ("dopri8", 12)]
MODES = ["backprop", "remat_step", "adjoint", "symplectic"]
MODE_LABEL = {"backprop": "backprop", "remat_step": "ACA",
              "adjoint": "adjoint", "symplectic": "symplectic(ours)"}


def run(batch: int = 256, n_steps: int = 8):
    data = make_tabular_dataset("gas", n=batch)
    u = jnp.asarray(data)
    eps = jax.random.normal(jax.random.PRNGKey(1), u.shape)
    out = {}
    for method, s in METHODS:
        for mode in MODES:
            cfg = CNFConfig(dim=u.shape[1], hidden=(64, 64),
                            n_components=1, method=method, grad_mode=mode,
                            n_steps=n_steps)
            params = init_cnf(jax.random.PRNGKey(0), cfg)

            @jax.jit
            def lg(params, u, eps):
                return jax.value_and_grad(cnf_nll)(params, u, eps, cfg)

            mem = live_bytes(lg, params, u, eps)
            t = time_call(lambda p: lg(p, u, eps), params, iters=2)
            out[(method, mode)] = dict(mem=mem, t=t)
            row(f"rk_{method}_s{s}_{MODE_LABEL[mode]}", t * 1e6,
                f"mem_mb={mem/2**20:.2f}")
    return out


def main():
    if smoke():
        run(batch=16, n_steps=2)
    else:
        run()


if __name__ == "__main__":
    main()
