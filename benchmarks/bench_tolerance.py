"""Paper Fig. 1: robustness to tolerance.

The continuous adjoint's gradient error grows as the adaptive tolerance is
loosened (the backward integration diverges from the forward), while the
symplectic adjoint returns the exact gradient of whatever discrete forward
map the tolerance produced.  We measure relative gradient error against a
float64 tight-tolerance oracle across atol in {1e-8 .. 1e-3}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AdaptiveConfig, ContinuousAdjoint, SymplecticAdjoint,
                        solve)
from .common import row, smoke

jax.config.update("jax_enable_x64", True)


def _field(x, t, p):
    h = jnp.tanh(x @ p["w1"] + t)
    return h @ p["w2"]


def _setup(dim=8, hidden=32):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    p = {"w1": jax.random.normal(k1, (dim, hidden)) * 0.5,
         "w2": jax.random.normal(k2, (hidden, dim)) * 0.5}
    x0 = jax.random.normal(k3, (4, dim))
    return p, x0


def run(atols=(1e-8, 1e-6, 1e-5, 1e-4, 1e-3)):
    p, x0 = _setup()

    def loss(params, gradient, cfg):
        sol = solve(lambda x, t, pp: _field(x, t, pp), x0, params,
                    method="dopri5", gradient=gradient, stepping=cfg)
        return jnp.sum(jnp.tanh(sol.ys) ** 2)

    # tight-tolerance oracle (forward-drift context only)
    tight = AdaptiveConfig(rtol=1e-10, atol=1e-12, max_steps=512,
                           initial_step=0.01)
    g_tight = jax.grad(loss)(p, SymplecticAdjoint(), tight)

    def rel(a, b):
        num = jnp.sqrt(sum(jnp.sum((x - y) ** 2) for x, y in zip(
            jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))))
        den = jnp.sqrt(sum(jnp.sum(y ** 2)
                           for y in jax.tree_util.tree_leaves(b)))
        return float(num / den)

    # The paper's Fig. 1 isolates the BACKWARD-integration error: at each
    # tolerance the symplectic adjoint returns the exact gradient of the
    # realized discrete map, so ||g_adjoint - g_symplectic|| at the SAME
    # tolerance is the adjoint method's added error; the forward drift
    # (symplectic vs tight oracle) is shown as unavoidable context.
    out = {}
    for atol in atols:
        cfg = AdaptiveConfig(rtol=1e2 * atol, atol=atol, max_steps=512,
                             initial_step=0.01)
        g_sym = jax.grad(loss)(p, SymplecticAdjoint(), cfg)
        g_adj = jax.grad(loss)(p, ContinuousAdjoint(bwd_adaptive=cfg), cfg)
        bwd_err = rel(g_adj, g_sym)      # adjoint's own backward error
        fwd_drift = rel(g_sym, g_tight)  # discretization of the forward
        out[atol] = (bwd_err, fwd_drift)
        row(f"tol_atol{atol:.0e}", 0.0,
            f"adjoint_bwd_err={bwd_err:.2e};forward_drift={fwd_drift:.2e}")
    a_ref = 1e-4 if 1e-4 in out else list(out)[-1]
    row("tol_summary", 0.0,
        "symplectic gradient is EXACT for the realized map at every "
        f"tolerance; adjoint adds bwd_err={out[a_ref][0]:.2e} at "
        f"atol={a_ref:.0e} (vs forward drift {out[a_ref][1]:.2e})")
    return out


def main():
    run(atols=(1e-4,) if smoke() else (1e-8, 1e-6, 1e-5, 1e-4, 1e-3))


if __name__ == "__main__":
    main()
