"""Benchmark utilities: wall-time + structural-memory measurement.

Memory on this CPU container is measured STRUCTURALLY: the compiled
artifact's live-buffer requirement (argument + output + temp - aliased
bytes from compiled.memory_analysis()).  This is exactly the quantity the
paper's Table 1/2/3 memory columns model (what must be resident during one
optimization step), and it is what a TPU deployment must fit in HBM.
"""
from __future__ import annotations

import os
import time

import jax


def smoke() -> bool:
    """True when running under ``benchmarks.run --smoke``.

    Smoke mode shrinks every benchmark to rot-check sizes (seconds, not
    minutes) so CI can execute the full driver on every push — the numbers
    are meaningless, the point is that the scripts still run.
    """
    return os.environ.get("REPRO_BENCH_SMOKE") == "1"


def time_call(fn, *args, iters: int = 3, warmup: int = 1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def live_bytes(jitted, *args) -> int:
    """Peak live bytes of the compiled program (structural memory)."""
    compiled = jitted.lower(*args).compile()
    m = compiled.memory_analysis()
    return (m.argument_size_in_bytes + m.output_size_in_bytes
            + m.temp_size_in_bytes - m.alias_size_in_bytes)


def temp_bytes(jitted, *args) -> int:
    compiled = jitted.lower(*args).compile()
    return compiled.memory_analysis().temp_size_in_bytes


def row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
