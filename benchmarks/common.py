"""Benchmark utilities: wall-time + structural-memory measurement.

Memory on this CPU container is measured STRUCTURALLY: the compiled
artifact's live-buffer requirement (argument + output + temp - aliased
bytes from compiled.memory_analysis()).  This is exactly the quantity the
paper's Table 1/2/3 memory columns model (what must be resident during one
optimization step), and it is what a TPU deployment must fit in HBM.
"""
from __future__ import annotations

import os
import time

import jax

# ---------------------------------------------------------------------------
# Machine-readable bench records (the CI perf trajectory).
#
# Every printed CSV row is also collected here; ``benchmarks.run`` dumps the
# records of each bench to BENCH_<name>.json and CI uploads them as an
# artifact, so the bench trajectory is queryable across commits instead of
# living only in job logs.  ``time_call`` additionally remembers the duration
# of its first warmup call — on a fresh function that is compile + one run,
# the compile-time proxy attached to the next ``row()`` (only when exactly
# one time_call preceded it, so the attribution is unambiguous).
# ---------------------------------------------------------------------------

_RECORDS: list = []
_LAST_FIRST_CALL_S: list = [None]
_CALLS_SINCE_ROW: list = [0]


def reset_records() -> None:
    _RECORDS.clear()
    _LAST_FIRST_CALL_S[0] = None
    _CALLS_SINCE_ROW[0] = 0


def get_records() -> list:
    return list(_RECORDS)


def record(name: str, **metrics) -> None:
    _RECORDS.append({"name": name, **metrics})


def smoke() -> bool:
    """True when running under ``benchmarks.run --smoke``.

    Smoke mode shrinks every benchmark to rot-check sizes (seconds, not
    minutes) so CI can execute the full driver on every push — the numbers
    are meaningless, the point is that the scripts still run.
    """
    return os.environ.get("REPRO_BENCH_SMOKE") == "1"


def time_call(fn, *args, iters: int = 3, warmup: int = 1):
    _CALLS_SINCE_ROW[0] += 1
    for i in range(warmup):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        if i == 0:
            _LAST_FIRST_CALL_S[0] = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def live_bytes(jitted, *args) -> int:
    """Peak live bytes of the compiled program (structural memory)."""
    compiled = jitted.lower(*args).compile()
    m = compiled.memory_analysis()
    return (m.argument_size_in_bytes + m.output_size_in_bytes
            + m.temp_size_in_bytes - m.alias_size_in_bytes)


def temp_bytes(jitted, *args) -> int:
    compiled = jitted.lower(*args).compile()
    return compiled.memory_analysis().temp_size_in_bytes


def row(name: str, us_per_call: float, derived: str = "", **metrics):
    print(f"{name},{us_per_call:.1f},{derived}")
    rec = {"us_per_call": round(us_per_call, 3), "derived": derived}
    # attach the compile-time proxy (first warmup call = compile + one run)
    # ONLY when exactly one time_call preceded this row — with several
    # measurements per row the attribution would be ambiguous, so drop it.
    if _LAST_FIRST_CALL_S[0] is not None and _CALLS_SINCE_ROW[0] == 1 \
            and "compile_s" not in metrics:
        rec["compile_s"] = round(_LAST_FIRST_CALL_S[0], 4)
    _LAST_FIRST_CALL_S[0] = None
    _CALLS_SINCE_ROW[0] = 0
    rec.update(metrics)
    record(name, **rec)
