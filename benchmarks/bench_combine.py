"""Fused vs unfused RK stage combination (the tentpole's HBM-pass claim).

The stage combination x + h*sum_i c_i k_i is memory-bound: ~s FLOPs per
element against (s+2)*4 bytes moved.  Three implementations of the dopri5
(s=7) update over a stacked slope buffer:

  unfused    — chained per-stage AXPY over a LIST of slope arrays
               (the pre-refactor tree_scale_add layout): s+2 HBM passes
  fused_jnp  — StageCombiner jnp oracle: stage-order accumulation over the
               stacked (s, n) buffer, fused by XLA into a single pass
  fused_pallas — the Pallas butcher_combine kernel (interpret mode on CPU,
               so only a small size is timed here; on TPU this is the
               compiled one-VMEM-pass path)

Reports wall time and the compiled live-buffer requirement (structural
memory, as in the other benches).  Also times a full fixed-grid dopri5
solve under combine_backend jnp to guard bench_rk_sweep-style workloads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.combine import alloc_stages, get_combiner, set_stage
from repro.core.rk import rk_solve_fixed, tree_scale_add
from repro.core.tableau import get_tableau
from repro.kernels.butcher_combine import butcher_combine_pallas
from .common import live_bytes, row, smoke, time_call

PALLAS_N = 1 << 14   # interpret mode is a python-driven interpreter: keep small


def _mk(n, s, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 2)
    x = jax.random.normal(ks[0], (n,), dtype=jnp.float32)
    K = jax.random.normal(ks[1], (s, n), dtype=jnp.float32)
    return x, K


def run(sizes=(1 << 16, 1 << 20), method: str = "dopri5"):
    tab = get_tableau(method)
    s = tab.s
    comb = get_combiner(tab, "jnp")
    h = jnp.float32(0.1)
    out = {}

    for n in sizes:
        x, K = _mk(n, s)
        klist = [K[i] for i in range(s)]

        @jax.jit
        def unfused(x, *klist):
            return tree_scale_add(
                x, [(tab.b[i], h * klist[i]) for i in range(s)])

        @jax.jit
        def fused_jnp(x, K):
            return comb.solution(x, K, h)

        t_un = time_call(lambda: unfused(x, *klist), iters=10, warmup=2)
        t_fu = time_call(lambda: fused_jnp(x, K), iters=10, warmup=2)
        m_un = live_bytes(unfused, x, *klist)
        m_fu = live_bytes(fused_jnp, x, K)
        out[n] = dict(t_unfused=t_un, t_fused=t_fu)
        row(f"combine_{method}_n{n}_unfused", t_un * 1e6,
            f"mem_mb={m_un/2**20:.2f}")
        row(f"combine_{method}_n{n}_fused_jnp", t_fu * 1e6,
            f"mem_mb={m_fu/2**20:.2f},speedup={t_un/t_fu:.2f}x")

    # Pallas path (interpret off-TPU: correctness/plumbing timing only).
    x, K = _mk(PALLAS_N, s)
    coefs = jnp.asarray(tab.b_dense, jnp.float32)
    t_pl = time_call(
        lambda: butcher_combine_pallas(x, K, coefs, h,
                                       interpret=jax.default_backend()
                                       != "tpu"),
        iters=3, warmup=1)
    row(f"combine_{method}_n{PALLAS_N}_fused_pallas", t_pl * 1e6,
        f"interpret={jax.default_backend() != 'tpu'}")

    # End-to-end guard: a fixed-grid solve through the combiner (the
    # bench_rk_sweep-shaped workload must not regress).
    def field(x, t, p):
        return jnp.tanh(p["w"] @ x)

    p = {"w": jax.random.normal(jax.random.PRNGKey(3), (64, 64),
                                dtype=jnp.float32) * 0.2}
    x0 = jax.random.normal(jax.random.PRNGKey(4), (64,), dtype=jnp.float32)

    @jax.jit
    def solve(x0, p):
        return rk_solve_fixed(field, tab, x0, 0.0, 1.0, 8, p).x_final

    t_solve = time_call(lambda: solve(x0, p), iters=5, warmup=2)
    row(f"combine_{method}_fixed_solve_n8", t_solve * 1e6, "")
    return out


def main():
    if smoke():
        run(sizes=(1 << 12,))
    else:
        run()


if __name__ == "__main__":
    main()
