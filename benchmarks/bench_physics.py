"""Paper Table 4: continuous-time physical systems (KdV, Cahn-Hilliard).

HNN++-style energy net, eighth-order Dormand-Prince (13 stages) — the
regime where the symplectic adjoint's O(s) stage-checkpoint advantage is
largest.  Reports long-term-prediction MSE, live memory, time/iter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data.physics_gen import generate_trajectories
from repro.models.physics import (PhysicsConfig, init_energy_net,
                                  physics_loss, rollout)
from .common import live_bytes, row, smoke, time_call

MODES = ["backprop", "remat_step", "adjoint", "symplectic"]
MODE_LABEL = {"backprop": "backprop", "remat_step": "ACA",
              "adjoint": "adjoint", "symplectic": "symplectic(ours)"}


def run(system: str = "kdv", steps: int = 80, grid: int = 64,
        substeps: int = 50, n_traj: int = 4):
    method = "dopri8" if "dopri8" in __import__(
        "repro.core.tableau", fromlist=["TABLEAUS"]).TABLEAUS else "dopri5"
    trajs = generate_trajectories(system, n_traj=n_traj, grid=grid,
                                  n_snapshots=12, substeps=substeps)
    u_k = jnp.asarray(trajs[:, :-1].reshape(-1, trajs.shape[-1]))
    u_k1 = jnp.asarray(trajs[:, 1:].reshape(-1, trajs.shape[-1]))
    out = {}
    for mode in MODES:
        cfg = PhysicsConfig(grid=grid, system=system, method=method,
                            grad_mode=mode, n_steps=4)
        params = init_energy_net(jax.random.PRNGKey(0), cfg)

        @jax.jit
        def lg(params, a, b):
            return jax.value_and_grad(physics_loss)(params, a, b, cfg)

        mem = live_bytes(lg, params, u_k[:32], u_k1[:32])
        t = time_call(lambda p: lg(p, u_k[:32], u_k1[:32]), params,
                      iters=2)
        # short training + long-term rollout MSE
        p = params
        lr = 3e-3
        for i in range(steps):
            lo = (i * 32) % (u_k.shape[0] - 32)
            _, g = lg(p, u_k[lo:lo + 32], u_k1[lo:lo + 32])
            p = jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)
        # rollout 5 snapshots from the first state of a held-out traj —
        # ONE multi-observation solve (SaveAt), not 5 chained solves
        preds = rollout(p, jnp.asarray(trajs[-1, 0:1]), cfg, 5)
        mse = float(jnp.mean((preds[:, 0]
                              - jnp.asarray(trajs[-1, 1:6])) ** 2))
        out[mode] = dict(mem=mem, t=t, mse=mse)
        row(f"physics_{system}_{method}_{MODE_LABEL[mode]}", t * 1e6,
            f"mem_mb={mem/2**20:.2f};rollout_mse={mse:.5f}")
    return out


def main():
    if smoke():
        run("kdv", steps=2, grid=32, substeps=10, n_traj=2)
    else:
        run("kdv")


if __name__ == "__main__":
    main()
