"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows AND writes one
``BENCH_<name>.json`` per bench (wall time + every recorded row with its
steady-state / compile-time metrics) so the perf trajectory is a queryable
artifact, not just job logs.  CI uploads ``BENCH_*.json`` from the
``--smoke`` job on every push.

  bench_tolerance       -> Fig. 1  (gradient error vs tolerance)
  bench_steps           -> Fig. 2  (memory vs number of steps)
  bench_orders          -> Table 1 (memory scaling orders in N, s, L)
  bench_cnf             -> Table 2 (CNF: NLL / memory / time per grad method)
  bench_rk_sweep        -> Table 3 (RK methods s=2,3,6,12)
  bench_physics         -> Table 4 (KdV / Cahn-Hilliard, dopri8)
  bench_combine         -> fused vs unfused stage combination (StageCombiner)
  bench_saveat_compile  -> SaveAt compile time vs observation count
  bench_batch           -> masked per-lane batching vs lockstep (batch_axis)
  bench_serve           -> continuous-batching engine vs sequential solving
  bench_checkpoint      -> blocking vs async checkpoint save stall, overlap
                           with compute, restore throughput (docs/training.md)
  bench_shard           -> mesh-sharded lanes vs 1 device (subprocess: the
                           forced host-device flag must precede jax init)
  roofline              -> EXPERIMENTS.md roofline (reads runs/dryrun.jsonl)

Usage:
    python -m benchmarks.run [--smoke] [bench_name]

``--smoke`` sets REPRO_BENCH_SMOKE=1 so every benchmark runs at tiny
rot-check sizes (CI executes this on every push; see .github/workflows).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
import traceback

from . import common


def _tolerance_subprocess():
    # bench_tolerance enables x64 globally; isolate it in a subprocess so
    # the f32 benches in this process are unaffected.  (Its rows are
    # recorded in the child process, so its BENCH json carries wall time
    # only.)
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_tolerance"],
        capture_output=True, text=True, timeout=1200)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr[-2000:])
        raise RuntimeError("bench_tolerance failed")


def _shard_subprocess():
    # bench_shard needs forced host devices, and the device-count flag only
    # takes effect BEFORE jax initializes its backend — this process's jax
    # is already up single-device, so the bench runs standalone.  The child
    # writes its own BENCH_bench_shard.json; lift its rows into this
    # process's records so the parent dump (which overwrites that file)
    # preserves them.
    env = dict(os.environ)
    if "--xla_force_host_platform_device_count" not in \
            env.get("XLA_FLAGS", ""):
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8").strip()
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_shard"],
        env=env, capture_output=True, text=True, timeout=1800)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr[-2000:])
        raise RuntimeError("bench_shard failed")
    try:
        with open("BENCH_bench_shard.json") as fh:
            for rec in json.load(fh).get("rows", []):
                common.record(**rec)
    except (FileNotFoundError, json.JSONDecodeError):
        pass


def _dump_bench_json(name: str, wall_s: float, ok: bool) -> None:
    payload = {
        "bench": name,
        "smoke": common.smoke(),
        "ok": ok,
        "wall_s": round(wall_s, 2),
        "rows": common.get_records(),
    }
    path = f"BENCH_{name}.json"
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"# wrote {path} ({len(payload['rows'])} rows)", flush=True)


def main() -> None:
    args = sys.argv[1:]
    if "--smoke" in args:
        args.remove("--smoke")
        # env (not a flag) so the bench_tolerance subprocess inherits it
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        print("# smoke mode: rot-check sizes, numbers are meaningless",
              flush=True)

    from . import (bench_batch, bench_checkpoint, bench_cnf, bench_combine,
                   bench_orders, bench_physics, bench_rk_sweep,
                   bench_saveat_compile, bench_serve, bench_steps, roofline)

    benches = [
        ("bench_tolerance", _tolerance_subprocess),
        ("bench_steps", bench_steps.main),
        ("bench_orders", bench_orders.main),
        ("bench_cnf", bench_cnf.main),
        ("bench_rk_sweep", bench_rk_sweep.main),
        ("bench_physics", bench_physics.main),
        ("bench_combine", bench_combine.main),
        ("bench_saveat_compile", bench_saveat_compile.main),
        ("bench_batch", bench_batch.main),
        ("bench_serve", bench_serve.main),
        ("bench_checkpoint", bench_checkpoint.main),
        ("bench_shard", _shard_subprocess),
        ("roofline", roofline.main),
    ]
    only = args[0] if args else None
    failed = []
    for name, fn in benches:
        if only and only != name:
            continue
        print(f"# === {name} ===", flush=True)
        common.reset_records()
        t0 = time.time()
        ok = True
        try:
            fn()
        except Exception:  # noqa: BLE001
            ok = False
            failed.append(name)
            traceback.print_exc()
        wall = time.time() - t0
        print(f"# {name} done in {wall:.1f}s", flush=True)
        _dump_bench_json(name, wall, ok)
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
