"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_tolerance  -> Fig. 1  (gradient error vs tolerance)
  bench_steps      -> Fig. 2  (memory vs number of steps)
  bench_orders     -> Table 1 (memory scaling orders in N, s, L)
  bench_cnf        -> Table 2 (CNF: NLL / memory / time per grad method)
  bench_rk_sweep   -> Table 3 (RK methods s=2,3,6,12)
  bench_physics    -> Table 4 (KdV / Cahn-Hilliard, dopri8)
  bench_combine    -> fused vs unfused stage combination (StageCombiner)
  roofline         -> EXPERIMENTS.md roofline (reads runs/dryrun.jsonl)

Usage:
    python -m benchmarks.run [--smoke] [bench_name]

``--smoke`` sets REPRO_BENCH_SMOKE=1 so every benchmark runs at tiny
rot-check sizes (CI executes this on every push; see .github/workflows).
"""
from __future__ import annotations

import os
import subprocess
import sys
import time
import traceback


def _tolerance_subprocess():
    # bench_tolerance enables x64 globally; isolate it in a subprocess so
    # the f32 benches in this process are unaffected.
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_tolerance"],
        capture_output=True, text=True, timeout=1200)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr[-2000:])
        raise RuntimeError("bench_tolerance failed")


def main() -> None:
    args = sys.argv[1:]
    if "--smoke" in args:
        args.remove("--smoke")
        # env (not a flag) so the bench_tolerance subprocess inherits it
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        print("# smoke mode: rot-check sizes, numbers are meaningless",
              flush=True)

    from . import (bench_cnf, bench_combine, bench_orders, bench_physics,
                   bench_rk_sweep, bench_steps, roofline)

    benches = [
        ("bench_tolerance", _tolerance_subprocess),
        ("bench_steps", bench_steps.main),
        ("bench_orders", bench_orders.main),
        ("bench_cnf", bench_cnf.main),
        ("bench_rk_sweep", bench_rk_sweep.main),
        ("bench_physics", bench_physics.main),
        ("bench_combine", bench_combine.main),
        ("roofline", roofline.main),
    ]
    only = args[0] if args else None
    failed = []
    for name, fn in benches:
        if only and only != name:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
