"""Mesh-sharded masked-batch solving: throughput scaling over host devices.

Workload: the heterogeneous-stiffness oscillator batch of bench_batch at
B=64 (omega log-spaced over ~1.5 decades, SORTED — so contiguous lane
blocks have genuinely different step-count demands), solved with adaptive
dopri5 three ways:

  * 1dev     — ``solve(..., batch_axis=0)``: the single-device masked
               per-lane driver.  Its fused while_loop runs until the
               SLOWEST lane of the whole batch finishes, evaluating all B
               lanes every trip.
  * sharded  — ``solve(..., batch_axis=0, mesh=(D,)-data mesh)``: each
               shard's while_loop stops at its OWN slowest lane, so easy
               shards retire early AND the shards run on separate devices.
  * grad     — same pair under ``jax.grad`` (symplectic adjoint), since
               training throughput is the quantity the paper cares about.

Reported per row: steady-state wall time, trajectories/s, the measured
speedup vs 1dev, the cross-shard ``load_imbalance`` metric (max/mean
per-shard accepted steps — 1.0 is perfectly balanced; the sorted-stiffness
batch is deliberately NOT), and ``ideal_speedup`` — the trip-count model
``B * max_lane_steps / (lanes_per_shard * max_shard_steps)``: what D-way
sharding buys when the devices are real cores (measured wall speedup
approaches it on a multi-core host; on a single-core container the forced
host devices serialize and the measured number reflects only the wasted-
work reduction).

Standalone (preferred — the device flag must precede jax init):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m benchmarks.bench_shard [--smoke]

writes BENCH_bench_shard.json itself; ``benchmarks.run`` wraps it in a
subprocess with the flag set and lifts the rows into its own dump.
"""
from __future__ import annotations

import json
import os
import sys

# must happen before jax initializes its backend; harmless if the parent
# already set a device count (standalone CI invocation does).
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp

from repro.core import AdaptiveConfig, SaveAt, solve
from .common import get_records, row, smoke, time_call


def field(state, t, params):
    x, om = state
    dx = params["gain"] * om[..., None] * jnp.stack(
        [x[..., 1], -x[..., 0]], axis=-1)
    return (dx, jnp.zeros_like(om))


PARAMS = {"gain": jnp.float32(1.0)}


def _setup(B, span=1.2):
    om = jnp.logspace(0.0, span, B)          # sorted: blocks differ in cost
    x0 = jax.random.normal(jax.random.PRNGKey(0), (B, 2))
    x0 = x0 / jnp.linalg.norm(x0, axis=-1, keepdims=True)
    return (x0, om)


def main() -> None:
    from repro.launch.mesh import make_lane_mesh
    B = 16 if smoke() else 64
    saveat = SaveAt(t1=1.0 if smoke() else 4.0)
    cfg = AdaptiveConfig(rtol=1e-5, atol=1e-7,
                         max_steps=128 if smoke() else 1024)
    state0 = _setup(B)
    devices = len(jax.devices())
    iters = 2 if smoke() else 5

    def solve_ys(x, mesh=None):
        kw = {"mesh": mesh} if mesh is not None else {}
        sol = solve(field, x, PARAMS, stepping=cfg, t0=0.0, batch_axis=0,
                    saveat=saveat, **kw)
        return sol.ys

    def loss(x, mesh=None):
        ys = solve_ys(x, mesh)
        return jnp.sum(ys[0] ** 2)

    base = jax.jit(solve_ys)
    s_base = time_call(base, state0, iters=iters)
    row("shard/value/1dev", s_base * 1e6, f"B={B}",
        trajectories_per_s=round(B / s_base, 1), devices=1)

    gbase = jax.jit(jax.grad(loss))
    s_gbase = time_call(gbase, state0, iters=iters)
    row("shard/grad/1dev", s_gbase * 1e6, f"B={B}",
        trajectories_per_s=round(B / s_gbase, 1), devices=1)

    if devices < 2:
        print("# only 1 device visible (set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8): "
              "skipping sharded rows")
        return

    mesh = make_lane_mesh((devices,))
    # stats pass (unjitted once): per-shard accepted steps + imbalance
    sol = solve(field, state0, PARAMS, stepping=cfg, batch_axis=0,
                saveat=saveat, mesh=mesh)
    n_steps = jax.device_get(sol.stats["n_steps"])
    shard_steps = jax.device_get(sol.stats["shard_steps"])
    imbalance = float(sol.stats["load_imbalance"])
    lanes_per_shard = B // devices
    # trip-count model: fused loops cost trips x lanes evaluated per trip
    work_1dev = int(n_steps.max()) * B
    work_shard = int(shard_steps.max() // lanes_per_shard + 1) \
        * lanes_per_shard
    ideal = work_1dev / max(work_shard, 1)

    sharded = jax.jit(lambda x: solve_ys(x, mesh))
    s_shard = time_call(sharded, state0, iters=iters)
    row("shard/value/sharded", s_shard * 1e6,
        f"B={B} D={devices}",
        trajectories_per_s=round(B / s_shard, 1), devices=devices,
        speedup=round(s_base / s_shard, 2),
        ideal_speedup=round(ideal, 2),
        load_imbalance=round(imbalance, 3),
        shard_steps=[int(s) for s in shard_steps])

    gshard = jax.jit(lambda x: jax.grad(loss)(x, mesh))
    s_gshard = time_call(gshard, state0, iters=iters)
    row("shard/grad/sharded", s_gshard * 1e6, f"B={B} D={devices}",
        trajectories_per_s=round(B / s_gshard, 1), devices=devices,
        speedup=round(s_gbase / s_gshard, 2))


def _dump_standalone() -> None:
    payload = {"bench": "bench_shard", "smoke": smoke(), "ok": True,
               "devices": len(jax.devices()), "rows": get_records()}
    with open("BENCH_bench_shard.json", "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"# wrote BENCH_bench_shard.json ({len(payload['rows'])} rows)",
          flush=True)


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    main()
    _dump_standalone()
