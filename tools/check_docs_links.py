#!/usr/bin/env python
"""Markdown cross-link checker: dead relative links in docs/ + README fail.

Scans README.md and every ``docs/**/*.md`` for inline markdown links
``[text](target)`` and verifies that each RELATIVE target resolves to an
existing file (anchors are stripped; external http(s)/mailto links and
pure-anchor links are skipped).  Also enforces the docs-index invariant:
every page under docs/ must be reachable (linked) from docs/README.md.

Usage (from the repo root):

    python tools/check_docs_links.py        # exit 1 on any dead link

Run by the CI docs lane and by tests/test_docs.py.
"""
from __future__ import annotations

import pathlib
import re
import sys
from typing import List, Tuple

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# inline links only; targets never contain spaces in this repo's docs
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def _doc_files(root: pathlib.Path) -> List[pathlib.Path]:
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def find_dead_links(root: pathlib.Path = REPO_ROOT
                    ) -> List[Tuple[str, str]]:
    """(source file, target) pairs whose relative target does not exist."""
    dead = []
    for f in _doc_files(root):
        for m in LINK_RE.finditer(f.read_text()):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (f.parent / path).exists():
                dead.append((str(f.relative_to(root)), target))
    return dead


def find_unreachable_docs(root: pathlib.Path = REPO_ROOT) -> List[str]:
    """docs/ pages not linked from the docs/README.md table of contents."""
    index = root / "docs" / "README.md"
    if not index.exists():
        return ["docs/README.md (the docs index itself is missing)"]
    linked = set()
    for m in LINK_RE.finditer(index.read_text()):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        linked.add((index.parent / target.split("#", 1)[0]).resolve())
    missing = []
    for page in sorted((root / "docs").glob("**/*.md")):
        if page.name == "README.md":
            continue
        if page.resolve() not in linked:
            missing.append(str(page.relative_to(root)))
    return missing


def main() -> int:
    dead = find_dead_links()
    unreachable = find_unreachable_docs()
    for src, target in dead:
        print(f"DEAD LINK  {src}: ({target})", file=sys.stderr)
    for page in unreachable:
        print(f"UNREACHABLE  {page}: not linked from docs/README.md",
              file=sys.stderr)
    if dead or unreachable:
        return 1
    n = len(_doc_files(REPO_ROOT))
    print(f"docs links OK ({n} markdown files checked)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
