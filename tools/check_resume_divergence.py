#!/usr/bin/env python
"""Fail if a resumed training run's loss curve diverges from the golden run.

    python tools/check_resume_divergence.py golden.jsonl resumed.jsonl \
        [--keys loss grad_norm lr] [--min-overlap 1]

Both files are ``--metrics-out`` JSONL from ``repro.launch.train`` (one
object per step).  Every step present in BOTH files must carry BIT-IDENTICAL
values for the compared keys — json.dumps round-trips python floats exactly,
so ``==`` on the parsed floats is an exact-bits comparison.  The symplectic
adjoint's exact-gradient property is what makes this a testable spec: there
is no tolerance to tune, the resumed curve either matches or the checkpoint
contract is broken.

Exit codes: 0 match, 1 divergence, 2 usage/empty-overlap.
"""
from __future__ import annotations

import argparse
import json
import sys


def load(path: str) -> dict:
    rows = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            rows[int(rec["step"])] = rec   # last write wins (resume overlap)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("golden")
    ap.add_argument("resumed")
    ap.add_argument("--keys", nargs="+", default=["loss", "grad_norm"])
    ap.add_argument("--min-overlap", type=int, default=1,
                    help="require at least this many common steps")
    args = ap.parse_args(argv)

    golden, resumed = load(args.golden), load(args.resumed)
    common = sorted(set(golden) & set(resumed))
    if len(common) < args.min_overlap:
        print(f"[check_resume] only {len(common)} overlapping steps "
              f"(need >= {args.min_overlap}) — nothing to compare",
              file=sys.stderr)
        return 2
    bad = 0
    for step in common:
        for k in args.keys:
            a, b = golden[step].get(k), resumed[step].get(k)
            if a != b:
                print(f"[check_resume] DIVERGED at step {step} {k}: "
                      f"golden={a!r} resumed={b!r}", file=sys.stderr)
                bad += 1
    if bad:
        print(f"[check_resume] {bad} divergent values over {len(common)} "
              f"common steps", file=sys.stderr)
        return 1
    print(f"[check_resume] OK: {len(common)} common steps bit-identical "
          f"on {args.keys}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
