"""Batch-native adaptive solving: masked per-lane step control.

Acceptance criteria pinned here (ISSUE 5):
  * batched exactness — for a heterogeneous-stiffness batch,
    ``solve(..., batch_axis=0)`` values, per-lane stats, accepted grids,
    and symplectic-adjoint / continuous-adjoint gradients match a Python
    loop of single-trajectory solves to rounding error;
  * masked per-lane control needs fewer total per-trajectory f-evals than
    lockstep batch-in-state solving on a heterogeneous batch;
  * per-lane failure isolation — one lane exhausting its budgets poisons
    (and flags) only itself;
  * the adaptive ``_error_norm`` applies per-leaf atol/rtol scaling
    identically in the batched and unbatched paths, including
    mixed-magnitude pytree states.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import (AdaptiveConfig, ContinuousAdjoint, DirectBackprop,
                        GradientStrategy, RematStep, SaveAt, SymplecticAdjoint,
                        batched_capability_matrix, capability_matrix,
                        lane_count, solve)
from repro.core.rk import (_error_norm, _error_norm_lanes,
                           apply_on_failure_lanes, rk_solve_adaptive,
                           rk_solve_adaptive_batched)
from repro.core.tableau import get_tableau

B = 4
TS = jnp.array([0.4, 0.7, 1.0])


def osc_field(state, t, p):
    """Per-lane oscillator: stiffness omega rides in the state (zero
    dynamics), the nonlinear coupling makes param gradients nonzero."""
    x, om = state
    h = jnp.tanh(x @ p["w"])
    dx = om[..., None] * jnp.stack(
        [x[..., 1] + h[..., 0], -x[..., 0] + h[..., 1]], axis=-1)
    return (dx, jnp.zeros_like(om))


PARAMS = {"w": jax.random.normal(jax.random.PRNGKey(0), (2, 2)) * 0.4}
OMEGAS = jnp.logspace(0.0, 1.2, B)          # ~1x .. ~16x stiffness spread
X0 = (jax.random.normal(jax.random.PRNGKey(1), (B, 2)), OMEGAS)
CFG = AdaptiveConfig(rtol=1e-7, atol=1e-9, max_steps=192, initial_step=0.05)


def lane(b):
    return (X0[0][b], X0[1][b])


def tree_maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x - y))) for x, y in
               zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


# ---------------------------------------------------------------------------
# Values, grids, and per-lane stats vs a Python loop of single solves
# ---------------------------------------------------------------------------

def test_batched_t1_values_and_stats_match_singles():
    sol = jax.jit(lambda x: solve(osc_field, x, PARAMS, stepping=CFG,
                                  gradient=DirectBackprop(),
                                  batch_axis=0))(X0)
    assert sol.stats["n_steps"].shape == (B,)
    assert sol.success.shape == (B,)
    for b in range(B):
        one = solve(osc_field, lane(b), PARAMS, stepping=CFG,
                    gradient=DirectBackprop())
        assert tree_maxdiff((sol.ys[0][b], sol.ys[1][b]), one.ys) < 1e-12
        for k in ("n_steps", "n_fevals", "n_attempts"):
            assert int(sol.stats[k][b]) == int(one.stats[k]), (b, k)
        assert bool(sol.success[b]) and bool(one.success)
    # heterogeneous stiffness ⇒ heterogeneous per-lane step counts
    assert int(sol.stats["n_steps"][-1]) > int(sol.stats["n_steps"][0])


def test_batched_accepted_grids_match_singles():
    bat = rk_solve_adaptive_batched(osc_field, get_tableau("dopri5"), X0,
                                    0.0, 1.0, PARAMS, CFG, "jnp")
    for b in range(B):
        one = rk_solve_adaptive(osc_field, get_tableau("dopri5"), lane(b),
                                0.0, 1.0, PARAMS, CFG, "jnp")
        assert int(bat.n_accepted[b]) == int(one.n_accepted)
        n = int(one.n_accepted)
        # the accept/reject SEQUENCE is identical (n_accepted above); the
        # realized grid matches to f64 rounding error, not bit-for-bit:
        # XLA fuses the batched and single loop bodies differently, and
        # since _error_norm accumulates in the full state dtype (f64
        # here — the dtype-discipline fix; it used to quantize through
        # f32, which masked last-ulp state differences), those ulps
        # legitimately propagate into the controller's h.
        np.testing.assert_allclose(bat.ts[:n, b], one.ts[:n], rtol=0,
                                   atol=1e-11)
        np.testing.assert_allclose(bat.hs[:n, b], one.hs[:n], rtol=0,
                                   atol=1e-11)
        assert abs(float(bat.h_final[b] - one.h_final)) < 1e-11


def test_batched_saveat_values_and_stats_match_singles():
    sol = jax.jit(lambda x: solve(osc_field, x, PARAMS,
                                  saveat=SaveAt(ts=TS), stepping=CFG,
                                  gradient=DirectBackprop(),
                                  batch_axis=0))(X0)
    assert sol.ys[0].shape == (TS.shape[0], B, 2)
    for b in range(B):
        one = solve(osc_field, lane(b), PARAMS, saveat=SaveAt(ts=TS),
                    stepping=CFG, gradient=DirectBackprop())
        assert tree_maxdiff((sol.ys[0][:, b], sol.ys[1][:, b]),
                            one.ys) < 1e-12
        for k in ("n_steps", "n_fevals", "n_attempts"):
            assert int(sol.stats[k][b]) == int(one.stats[k]), (b, k)


def test_batched_reverse_time_matches_singles():
    sol = solve(osc_field, X0, PARAMS, saveat=SaveAt(t1=-0.5),
                stepping=CFG, gradient=DirectBackprop(), batch_axis=0)
    for b in range(B):
        one = solve(osc_field, lane(b), PARAMS, saveat=SaveAt(t1=-0.5),
                    stepping=CFG, gradient=DirectBackprop())
        assert tree_maxdiff((sol.ys[0][b], sol.ys[1][b]), one.ys) < 1e-12


def test_fixed_grid_batched_is_plain_solve_with_lane_stats():
    sol_b = solve(osc_field, X0, PARAMS, stepping=8, batch_axis=0)
    sol_p = solve(osc_field, X0, PARAMS, stepping=8)
    assert tree_maxdiff(sol_b.ys, sol_p.ys) == 0.0
    assert sol_b.stats["n_steps"].shape == (B,)
    assert jnp.all(sol_b.stats["n_steps"] == int(sol_p.stats["n_steps"]))
    assert sol_b.success.shape == (B,) and bool(jnp.all(sol_b.success))


# ---------------------------------------------------------------------------
# Gradients: batched backward passes replay each lane's own grid
# ---------------------------------------------------------------------------

def _loop_grads(loss_one):
    gx, gom, gp = [], [], None
    for b in range(B):
        (gxb, gob), gpb = jax.grad(loss_one, argnums=(0, 1))(lane(b), PARAMS)
        gx.append(gxb)
        gom.append(gob)
        gp = gpb if gp is None else tree_add(gp, gpb)
    return (jnp.stack(gx), jnp.stack(gom)), gp


@pytest.mark.parametrize("gradient", [SymplecticAdjoint(),
                                      ContinuousAdjoint()],
                         ids=["symplectic", "adjoint"])
def test_batched_t1_gradient_matches_singles(gradient):
    def loss_b(x, p):
        ys = solve(osc_field, x, p, stepping=CFG, gradient=gradient,
                   batch_axis=0).ys
        return jnp.sum(ys[0] ** 2)

    def loss_one(x_l, p):
        ys = solve(osc_field, x_l, p, stepping=CFG, gradient=gradient).ys
        return jnp.sum(ys[0] ** 2)

    gb_x, gb_p = jax.jit(jax.grad(loss_b, argnums=(0, 1)))(X0, PARAMS)
    gs_x, gs_p = _loop_grads(loss_one)
    assert tree_maxdiff(gb_x, gs_x) < 1e-9
    assert tree_maxdiff(gb_p, gs_p) < 1e-9


@pytest.mark.parametrize("gradient", [SymplecticAdjoint(),
                                      ContinuousAdjoint()],
                         ids=["symplectic", "adjoint"])
def test_batched_saveat_gradient_matches_singles(gradient):
    def loss_b(x, p):
        ys = solve(osc_field, x, p, saveat=SaveAt(ts=TS), stepping=CFG,
                   gradient=gradient, batch_axis=0).ys
        return jnp.sum(ys[0] ** 2) + jnp.sum(ys[0][0] * ys[0][-1])

    def loss_one(x_l, p):
        ys = solve(osc_field, x_l, p, saveat=SaveAt(ts=TS), stepping=CFG,
                   gradient=gradient).ys
        return jnp.sum(ys[0] ** 2) + jnp.sum(ys[0][0] * ys[0][-1])

    gb_x, gb_p = jax.jit(jax.grad(loss_b, argnums=(0, 1)))(X0, PARAMS)
    gs_x, gs_p = _loop_grads(loss_one)
    assert tree_maxdiff(gb_x, gs_x) < 1e-9
    assert tree_maxdiff(gb_p, gs_p) < 1e-9


@pytest.mark.slow  # the reference unrolls ~1k replay steps under jax.grad
def test_symplectic_batched_gradient_is_exact_vs_backprop_replay():
    """The batched symplectic gradient equals jax.grad through a fixed-grid
    replay of each lane's realized step sequence (Theorem 2 per lane)."""
    tab = get_tableau("bosh3")
    # bosh3 is order 3: the stiffest lane needs ~1k accepted steps here
    cfg = dataclasses.replace(CFG, rtol=1e-5, atol=1e-7, max_steps=1536,
                              max_attempts=8192)

    def loss_b(x, p):
        ys = solve(osc_field, x, p, method="bosh3", stepping=cfg,
                   gradient=SymplecticAdjoint(), batch_axis=0).ys
        return jnp.sum(ys[0] ** 2)

    gb_x, gb_p = jax.grad(loss_b, argnums=(0, 1))(X0, PARAMS)

    # replay each lane's accepted (t, h) sequence with plain backprop
    from repro.core.rk import rk_step
    gs_x0, gs_om, gs_p = [], [], None
    for b in range(B):
        sol = rk_solve_adaptive(osc_field, tab, lane(b), 0.0, 1.0, PARAMS,
                                cfg, "jnp")
        n = int(sol.n_accepted)
        ts_b, hs_b = np.asarray(sol.ts[:n]), np.asarray(sol.hs[:n])

        def replay(x_l, p):
            x = x_l
            for t_n, h_n in zip(ts_b, hs_b):
                x, _ = rk_step(osc_field, tab, x, t_n, h_n, p,
                               with_error=False)
            return jnp.sum(x[0] ** 2)

        (gxb, gob), gpb = jax.grad(replay, argnums=(0, 1))(lane(b), PARAMS)
        gs_x0.append(gxb)
        gs_om.append(gob)
        gs_p = gpb if gs_p is None else tree_add(gs_p, gpb)
    assert tree_maxdiff(gb_x, (jnp.stack(gs_x0), jnp.stack(gs_om))) < 1e-9
    assert tree_maxdiff(gb_p, gs_p) < 1e-9


# ---------------------------------------------------------------------------
# The acceptance number: masked beats lockstep on per-trajectory f-evals
# ---------------------------------------------------------------------------

def test_masked_needs_fewer_trajectory_fevals_than_lockstep():
    masked = solve(osc_field, X0, PARAMS, stepping=CFG,
                   gradient=DirectBackprop(), batch_axis=0)
    lockstep = solve(osc_field, X0, PARAMS, stepping=CFG,
                     gradient=DirectBackprop())
    fe_masked = int(jnp.sum(masked.stats["n_fevals"]))
    fe_lockstep = B * int(lockstep.stats["n_fevals"])
    assert fe_masked < fe_lockstep, (fe_masked, fe_lockstep)


# ---------------------------------------------------------------------------
# _error_norm: per-leaf scaling is identical batched and unbatched
# ---------------------------------------------------------------------------

def _mixed_state(b=None):
    big = 1e3 * jax.random.normal(jax.random.PRNGKey(2), (B, 3))
    small = 1e-3 * jax.random.normal(jax.random.PRNGKey(3), (B, 2))
    if b is None:
        return {"big": big, "small": small}
    return {"big": big[b], "small": small[b]}


def test_error_norm_lanes_equals_per_lane_error_norm():
    x, xn = _mixed_state(), jax.tree_util.tree_map(
        lambda l: l * 1.001 + 1e-6, _mixed_state())
    err = jax.tree_util.tree_map(lambda a, b: (b - a) * 0.01, x, xn)
    lanes = _error_norm_lanes(err, x, xn, 1e-6, 1e-8)
    assert lanes.shape == (B,)
    for b in range(B):
        one = _error_norm(
            jax.tree_util.tree_map(lambda l: l[b], err),
            jax.tree_util.tree_map(lambda l: l[b], x),
            jax.tree_util.tree_map(lambda l: l[b], xn), 1e-6, 1e-8)
        assert float(jnp.abs(lanes[b] - one)) == 0.0


def test_error_norm_matches_elementwise_reference():
    """Pin the norm semantics: elementwise Hairer scale per leaf
    (atol + rtol * max(|x|, |x_next|)), element-count-weighted RMS across
    ALL leaves — i.e. per-leaf atol scaling, no max-reduction and no
    per-leaf averaging that would over-weight small leaves."""
    x, xn = _mixed_state(0), _mixed_state(1)
    err = jax.tree_util.tree_map(lambda a, b: 0.3 * (b - a), x, xn)
    rtol, atol = 1e-4, 1e-7
    total, count = 0.0, 0
    for k in ("big", "small"):
        scale = atol + rtol * np.maximum(np.abs(np.asarray(x[k])),
                                         np.abs(np.asarray(xn[k])))
        r = np.float32(np.asarray(err[k]) / scale)
        total += float(np.sum(r * r))
        count += r.size
    ref = np.sqrt(total / count)
    got = float(_error_norm(err, x, xn, rtol, atol))
    np.testing.assert_allclose(got, ref, rtol=1e-6)


def test_mixed_magnitude_batched_grid_matches_singles():
    """Accepted grids for a mixed-magnitude pytree state agree lane-by-lane
    between the batched and single-trajectory controllers."""
    def decay(state, t, p):
        return jax.tree_util.tree_map(
            lambda l: -p["k"] * l * (1.0 + 0.5 * jnp.tanh(l / 1e3)), state)

    x0 = _mixed_state()
    p = {"k": jnp.asarray(1.7)}
    cfg = AdaptiveConfig(rtol=1e-6, atol=1e-9, max_steps=128,
                         initial_step=0.05)
    tab = get_tableau("bosh3")
    bat = rk_solve_adaptive_batched(decay, tab, x0, 0.0, 1.0, p, cfg, "jnp")
    for b in range(B):
        one = rk_solve_adaptive(decay, tab,
                                jax.tree_util.tree_map(lambda l: l[b], x0),
                                0.0, 1.0, p, cfg, "jnp")
        assert int(bat.n_accepted[b]) == int(one.n_accepted)
        n = int(one.n_accepted)
        # rounding-error scale, not bit-for-bit — same fusion-order caveat
        # as test_batched_accepted_grids_match_singles
        np.testing.assert_allclose(bat.hs[:n, b], one.hs[:n], rtol=0,
                                   atol=1e-11)


# ---------------------------------------------------------------------------
# Per-lane failure isolation
# ---------------------------------------------------------------------------

def test_failed_lane_is_poisoned_and_flagged_alone():
    # a step budget the stiffest lane cannot meet, the easiest easily can
    # (at CFG's tolerances the per-lane accepted counts span ~6..35)
    tight = dataclasses.replace(CFG, max_steps=24)
    sol = solve(osc_field, X0, PARAMS, stepping=tight,
                gradient=DirectBackprop(), batch_axis=0)
    ok = np.asarray(sol.success)
    assert bool(ok[0]) and not bool(ok[-1])   # easy lane fine, stiff fails
    assert bool(jnp.all(jnp.isfinite(sol.ys[0][0])))
    assert bool(jnp.all(jnp.isnan(sol.ys[0][-1])))
    # healthy lanes still match their single solves
    one = solve(osc_field, lane(0), PARAMS, stepping=tight,
                gradient=DirectBackprop())
    assert tree_maxdiff((sol.ys[0][0], sol.ys[1][0]), one.ys) < 1e-12


def test_poisoned_lane_does_not_burn_max_attempts_in_later_segments():
    """A lane NaN-poisoned in an early SaveAt segment must drop out of the
    batched while_loop after ONE doomed trial per later segment (the NaN h
    carry bail), not pin every healthy lane behind max_attempts full-batch
    steps."""
    tight = dataclasses.replace(CFG, max_steps=24, max_attempts=4096)
    ts = jnp.linspace(0.25, 1.0, 4)
    sol = solve(osc_field, X0, PARAMS, saveat=SaveAt(ts=ts), stepping=tight,
                gradient=DirectBackprop(), batch_axis=0)
    ok = np.asarray(sol.success)
    assert bool(ok[0]) and not bool(ok[-1])
    # dead lane: max_steps-ish attempts in its failing segment, then ~1 per
    # later segment — nowhere near segments * max_attempts
    assert int(sol.stats["n_attempts"][-1]) < 200
    # healthy lanes still match their single solves exactly
    one = solve(osc_field, lane(0), PARAMS, saveat=SaveAt(ts=ts),
                stepping=tight, gradient=DirectBackprop())
    assert tree_maxdiff((sol.ys[0][:, 0], sol.ys[1][:, 0]), one.ys) < 1e-12
    assert int(sol.stats["n_attempts"][0]) == int(one.stats["n_attempts"])


def test_nan_state_solve_bails_instead_of_spinning():
    """Single-trajectory analogue: a NaN initial state exits the adaptive
    loop after one trial instead of burning the max_attempts budget."""
    sol = rk_solve_adaptive(osc_field, get_tableau("dopri5"),
                            (jnp.full((2,), jnp.nan), jnp.float64(1.0)),
                            0.0, 1.0, PARAMS, CFG, "jnp")
    assert not bool(sol.succeeded)
    assert int(sol.n_attempts) <= 2


def test_apply_on_failure_lanes_policies():
    x = {"a": jnp.ones((3, 2)), "n": jnp.ones((3,), jnp.int32)}
    ok = jnp.array([True, False, True])
    out = apply_on_failure_lanes(x, ok, "nan")
    assert bool(jnp.all(jnp.isfinite(out["a"][0])))
    assert bool(jnp.all(jnp.isnan(out["a"][1])))
    assert bool(jnp.all(out["n"] == 1))       # integer leaves untouched
    out = apply_on_failure_lanes(x, ok, "ignore")
    assert tree_maxdiff(out, x) == 0.0


# ---------------------------------------------------------------------------
# Capability matrix, validation, and the shim
# ---------------------------------------------------------------------------

def test_batched_capability_matrix_contents():
    m = batched_capability_matrix()
    assert set(m) == set(capability_matrix())
    for name in ("symplectic", "backprop", "adjoint"):
        assert m[name][("adaptive", "t1")] and m[name][("adaptive", "ts")]
    for name in ("remat_step", "remat_solve"):
        assert not m[name][("adaptive", "t1")]
        assert m[name][("fixed", "t1")]       # fixed grids batch for free
    assert not m["backprop"][("adaptive", "dense")]


def test_batched_capability_errors_are_uniform():
    with pytest.raises(ValueError, match="batch_axis=0"):
        solve(osc_field, X0, PARAMS, stepping=CFG, gradient=RematStep(),
              batch_axis=0)
    with pytest.raises(ValueError, match="batch_axis=0"):
        solve(osc_field, X0, PARAMS, saveat=SaveAt(ts=TS, dense=True),
              stepping=CFG, gradient=DirectBackprop(), batch_axis=0)


def test_batch_axis_validation():
    with pytest.raises(ValueError, match="only the leading axis"):
        solve(osc_field, X0, PARAMS, stepping=CFG, batch_axis=1)
    with pytest.raises(ValueError, match="leading lane axis"):
        solve(osc_field, (X0[0], jnp.float64(1.0)), PARAMS, stepping=CFG,
              batch_axis=0)
    with pytest.raises(ValueError, match="same leading lane-axis size"):
        lane_count((jnp.ones((3, 2)), jnp.ones((4,))))


def test_toy_strategy_batched_cells_default():
    class Toy(GradientStrategy):
        name = "toy_batched_cells"
        capabilities = frozenset({("fixed", "t1"), ("adaptive", "t1")})

    # fixed cells batch for free; adaptive cells need an explicit driver
    assert Toy.batched_cells() == frozenset({("fixed", "t1")})


@pytest.mark.filterwarnings(
    "ignore:odeint-style entry point:DeprecationWarning")
def test_odeint_shim_passes_batch_axis_through():
    from repro.core import odeint
    ys = odeint(osc_field, X0, PARAMS, t1=1.0, adaptive=CFG,
                grad_mode="backprop", batch_axis=0)
    sol = solve(osc_field, X0, PARAMS, saveat=SaveAt(t1=1.0), stepping=CFG,
                gradient=DirectBackprop(), batch_axis=0)
    assert tree_maxdiff(ys, sol.ys) == 0.0


# ---------------------------------------------------------------------------
# Backend parity
# ---------------------------------------------------------------------------

def test_batched_pallas_backend_matches_jnp():
    cfg = dataclasses.replace(CFG, rtol=1e-5, atol=1e-7, max_steps=64)
    sol_j = solve(osc_field, X0, PARAMS, stepping=cfg,
                  gradient=DirectBackprop(), batch_axis=0, backend="jnp")
    sol_p = solve(osc_field, X0, PARAMS, stepping=cfg,
                  gradient=DirectBackprop(), batch_axis=0, backend="pallas")
    assert bool(jnp.all(sol_j.success)) and bool(jnp.all(sol_p.success))
    assert tree_maxdiff(sol_j.ys, sol_p.ys) < 1e-5
