"""Exactness tests for the stacked-stage StageCombiner refactor.

Covers the PR's acceptance criteria:
  * for every registered tableau, symplectic gradients through the new
    stacked-stage path match jax.grad of the discrete forward map to
    rounding error (f64), on fixed grids AND on adaptive grids (via replay
    of the realized step sequence, since while_loop is not reverse-diff);
  * the Pallas combiner kernels (interpret mode) match the jnp oracles to
    final rounding on odd/padded shapes — identical f32 accumulation order,
    so the only permitted divergence is compiler FMA contraction of a
    mul+add pair (< 2 ulp of the result scale);
  * the combiner backend is actually exercised by odeint (butcher_combine
    is solver hot path, not dead code);
  * the fixed-grid driver skips the embedded error estimate (and its extra
    network evaluation for err_uses_fsal tableaus).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import TABLEAUS, AdaptiveConfig, get_combiner, odeint
from repro.core.combine import alloc_stages, set_stage
from repro.core.rk import rk_solve_fixed, rk_step, tree_scale_add
from repro.core.tableau import get_tableau
from repro.kernels import ops, ref
from repro.kernels.butcher_combine import (butcher_combine_pallas,
                                           butcher_combine_rows_pallas)

# Deliberately exercises the deprecated odeint shim (shim regression suite).
pytestmark = pytest.mark.filterwarnings(
    "ignore:odeint-style entry point:DeprecationWarning")

ALL_METHODS = sorted(TABLEAUS)
ADAPTIVE_METHODS = [n for n in ALL_METHODS if TABLEAUS[n].b_err is not None]


def mlp_field(x, t, params):
    h = jnp.tanh(params["w1"] @ x + params["b1"] + t)
    return params["w2"] @ h + params["b2"]


def mlp_field_f32(x, t, params):
    # keep the field's output dtype pinned to the (f32) state dtype even
    # under jax_enable_x64, where the solver's t is f64
    return mlp_field(x, jnp.asarray(t).astype(x.dtype), params)


def make_params(key, dim=4, hidden=6):
    ks = jax.random.split(key, 4)
    return {
        "w1": jax.random.normal(ks[0], (hidden, dim)) * 0.5,
        "b1": jax.random.normal(ks[1], (hidden,)) * 0.1,
        "w2": jax.random.normal(ks[2], (dim, hidden)) * 0.5,
        "b2": jax.random.normal(ks[3], (dim,)) * 0.1,
    }


# --- combiner vs the unfused chained-AXPY reference --------------------------

@pytest.mark.parametrize("method", ["dopri5", "dopri8", "rk4"])
def test_combiner_solution_matches_chained_axpy(method):
    tab = get_tableau(method)
    comb = get_combiner(tab, "jnp")
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (17,))
    K = alloc_stages(tab.s, x)
    ks = []
    for i in range(tab.s):
        k_i = jax.random.normal(jax.random.PRNGKey(10 + i), (17,))
        ks.append(k_i)
        K = set_stage(K, i, k_i)
    h = jnp.asarray(0.125)
    got = comb.solution(x, K, h)
    want = tree_scale_add(x, [(tab.b[i], h * ks[i]) for i in range(tab.s)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-14, atol=1e-14)


def test_solution_and_error_fused_matches_separate():
    tab = get_tableau("dopri5")
    comb = get_combiner(tab, "jnp")
    x = jax.random.normal(jax.random.PRNGKey(1), (33,))
    K = alloc_stages(tab.s, x)
    for i in range(tab.s):
        K = set_stage(K, i, jax.random.normal(jax.random.PRNGKey(i), (33,)))
    h = jnp.asarray(0.2)
    x_next, err = comb.solution_and_error(x, K, h)
    np.testing.assert_allclose(np.asarray(x_next),
                               np.asarray(comb.solution(x, K, h)),
                               rtol=1e-14)
    np.testing.assert_allclose(np.asarray(err),
                               np.asarray(comb.error(x, K, h)),
                               rtol=1e-13, atol=1e-15)


# --- gradient exactness through the stacked-stage path -----------------------

@pytest.mark.parametrize("method", ALL_METHODS)
def test_symplectic_matches_jax_grad_fixed_grid(method):
    params = make_params(jax.random.PRNGKey(0))
    x0 = jax.random.normal(jax.random.PRNGKey(1), (4,))

    def loss(x0, params, mode):
        y = odeint(mlp_field, x0, params, t0=0.0, t1=1.0, method=method,
                   grad_mode=mode, n_steps=5, combine_backend="jnp")
        return jnp.sum(jnp.sin(y) ** 2)

    g_ref = jax.grad(loss, argnums=(0, 1))(x0, params, "backprop")
    g_sym = jax.grad(loss, argnums=(0, 1))(x0, params, "symplectic")
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_sym)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-10, atol=1e-12)


@pytest.mark.parametrize("method,rtol", [
    ("heun12", 1e-3), ("bosh3", 1e-5), ("dopri5", 1e-6),
    pytest.param("fehlberg45", 1e-7, marks=pytest.mark.slow),
    pytest.param("dopri8", 1e-7, marks=pytest.mark.slow)])
def test_symplectic_matches_jax_grad_adaptive_grid(method, rtol):
    """Adaptive forward + symplectic backward == jax.grad of the REALIZED
    discrete map, for every tableau with an embedded error estimate.  The
    reference replays the recorded accepted {t_n, h_n} sequence as a
    differentiable unrolled solve (while_loop is not reverse-diff)."""
    from repro.core.rk import rk_solve_adaptive

    tab = get_tableau(method)
    params = make_params(jax.random.PRNGKey(4))
    x0 = jax.random.normal(jax.random.PRNGKey(5), (4,))
    cfg = AdaptiveConfig(rtol=rtol, atol=rtol * 1e-2, max_steps=128,
                         initial_step=0.05)

    sol = rk_solve_adaptive(mlp_field, tab, x0, 0.0, 0.5, params, cfg)
    n_acc = int(sol.n_accepted)
    assert 0 < n_acc < cfg.max_steps
    ts = np.asarray(sol.ts)[:n_acc]
    hs = np.asarray(sol.hs)[:n_acc]

    def loss_replay(x0, params):
        x = x0
        for t, h in zip(ts, hs):
            x, _ = rk_step(mlp_field, tab, x, jnp.asarray(t),
                           jnp.asarray(h), params)
        return jnp.sum(jnp.tanh(x) ** 2)

    def loss_sym(x0, params):
        y = odeint(mlp_field, x0, params, t0=0.0, t1=0.5, method=method,
                   grad_mode="symplectic", adaptive=cfg)
        return jnp.sum(jnp.tanh(y) ** 2)

    g_ref = jax.grad(loss_replay, argnums=(0, 1))(x0, params)
    g_sym = jax.grad(loss_sym, argnums=(0, 1))(x0, params)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_sym)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-9, atol=1e-11)


@pytest.mark.slow
def test_symplectic_pallas_backend_gradient_f32():
    """The Pallas-kernel combine path (f32 accumulate) stays within f32
    tolerance of the f64 jnp path on both forward and gradient."""
    params = jax.tree_util.tree_map(
        lambda l: l.astype(jnp.float32), make_params(jax.random.PRNGKey(2)))
    x0 = jax.random.normal(jax.random.PRNGKey(3), (4,), dtype=jnp.float32)

    def loss(x0, backend):
        y = odeint(mlp_field_f32, x0, params, method="bosh3",
                   grad_mode="symplectic", n_steps=3,
                   combine_backend=backend)
        return jnp.sum(y ** 2)

    y_p, y_j = loss(x0, "pallas"), loss(x0, "jnp")
    np.testing.assert_allclose(float(y_p), float(y_j), rtol=1e-5)
    g_p = jax.grad(loss)(x0, "pallas")
    g_j = jax.grad(loss)(x0, "jnp")
    np.testing.assert_allclose(np.asarray(g_p), np.asarray(g_j),
                               rtol=1e-5, atol=1e-5)


def test_backprop_differentiates_through_pallas_kernel():
    """grad through rk_solve_fixed with the Pallas backend (the combine
    custom-JVP) matches the jnp backend."""
    params = jax.tree_util.tree_map(
        lambda l: l.astype(jnp.float32), make_params(jax.random.PRNGKey(6)))
    x0 = jax.random.normal(jax.random.PRNGKey(7), (4,), dtype=jnp.float32)

    def loss(x0, backend):
        y = odeint(mlp_field_f32, x0, params, method="rk4",
                   grad_mode="backprop", n_steps=2, combine_backend=backend)
        return jnp.sum(y ** 2)

    g_p = jax.grad(loss)(x0, "pallas")
    g_j = jax.grad(loss)(x0, "jnp")
    np.testing.assert_allclose(np.asarray(g_p), np.asarray(g_j),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_grad_through_pallas_multirow_error_path():
    """rk_step with an embedded error estimate routes through the multi-row
    kernel (solution_and_error); it must stay reverse-differentiable under
    the Pallas backend (the adaptive replay tests differentiate rk_step
    with the default combiner, which is the Pallas path on TPU)."""
    tab = get_tableau("dopri5")
    params = jax.tree_util.tree_map(
        lambda l: l.astype(jnp.float32), make_params(jax.random.PRNGKey(8)))
    x0 = jax.random.normal(jax.random.PRNGKey(9), (4,), dtype=jnp.float32)
    h = jnp.float32(0.1)

    def loss(x0, backend):
        comb = get_combiner(tab, backend)
        x1, err = rk_step(mlp_field_f32, tab, x0, jnp.float32(0.0), h,
                          params, comb, with_error=True)
        return jnp.sum(x1 ** 2) + jnp.sum(err ** 2)

    g_p = jax.grad(loss)(x0, "pallas")
    g_j = jax.grad(loss)(x0, "jnp")
    np.testing.assert_allclose(np.asarray(g_p), np.asarray(g_j),
                               rtol=1e-4, atol=1e-5)


# --- the kernel is the hot path, not dead code -------------------------------

def test_odeint_exercises_combiner_backend(monkeypatch):
    """odeint(combine_backend="pallas") must route stage combination through
    kernels.ops.butcher_combine — forward AND symplectic backward."""
    calls = []
    orig = ops.butcher_combine

    def spy(*args, **kwargs):
        calls.append(1)
        return orig(*args, **kwargs)

    monkeypatch.setattr(ops, "butcher_combine", spy)
    params = jax.tree_util.tree_map(
        lambda l: l.astype(jnp.float32), make_params(jax.random.PRNGKey(8)))
    x0 = jax.random.normal(jax.random.PRNGKey(9), (4,), dtype=jnp.float32)

    def loss(x0):
        y = odeint(mlp_field_f32, x0, params, method="rk4",
                   grad_mode="symplectic", n_steps=2,
                   combine_backend="pallas")
        return jnp.sum(y ** 2)

    n0 = len(calls)
    loss(x0)
    n_fwd = len(calls) - n0
    assert n_fwd > 0, "forward solve did not reach butcher_combine"
    jax.grad(loss)(x0)
    assert len(calls) - n0 - n_fwd > 0, \
        "symplectic backward did not reach butcher_combine"


# --- Pallas kernels vs jnp oracles on odd/padded shapes ----------------------

def _final_rounding_atol(*arrays):
    scale = max(float(np.max(np.abs(np.asarray(a, np.float32)))) or 1.0
                for a in arrays)
    return 2 * np.finfo(np.float32).eps * max(scale, 1.0)


@pytest.mark.parametrize("n,s", [(1, 1), (129, 4), (257, 7), (1000, 13),
                                 (231, 6)])
def test_pallas_row_kernel_matches_oracle_odd_shapes(n, s):
    """Identical f32 stage-order accumulation: any divergence is compiler
    FMA contraction of one mul+add, bounded by final rounding (2 ulp at
    result scale)."""
    k = jax.random.split(jax.random.PRNGKey(n + s), 3)
    x = jax.random.normal(k[0], (n,), dtype=jnp.float32)
    ks = jax.random.normal(k[1], (s, n), dtype=jnp.float32)
    coefs = jax.random.normal(k[2], (s,), dtype=jnp.float32)
    h = jnp.float32(0.37)
    got = np.asarray(butcher_combine_pallas(x, ks, coefs, h, interpret=True))
    want = np.asarray(ref.butcher_combine_ref(x, ks, coefs, h))
    np.testing.assert_allclose(got, want, rtol=0,
                               atol=_final_rounding_atol(want, x, ks))


@pytest.mark.parametrize("n,s", [(1, 1), (129, 4), (1000, 13), (231, 7)])
def test_pallas_rows_kernel_matches_oracle_odd_shapes(n, s):
    k = jax.random.split(jax.random.PRNGKey(n * 3 + s), 3)
    x = jax.random.normal(k[0], (n,), dtype=jnp.float32)
    ks = jax.random.normal(k[1], (s, n), dtype=jnp.float32)
    coefs = jax.random.normal(k[2], (2, s), dtype=jnp.float32)
    scale = jnp.asarray([1.0, 0.0], jnp.float32)
    h = jnp.float32(0.21)
    got = np.asarray(butcher_combine_rows_pallas(x, ks, coefs, scale, h,
                                                 interpret=True))
    want = np.asarray(ref.butcher_combine_rows_ref(x, ks, coefs, scale, h))
    assert got.shape == (2, n)
    np.testing.assert_allclose(got, want, rtol=0,
                               atol=_final_rounding_atol(want, x, ks))


# --- fixed-grid drivers skip the embedded error estimate ---------------------

@pytest.mark.parametrize("method", ["dopri5", "dopri8"])
def test_fixed_grid_skips_error_estimate(method):
    """rk_solve_fixed must evaluate f exactly s times per step: no error
    combine, and (for err_uses_fsal tableaus like dopri8) no wasted extra
    f(x_{n+1}) evaluation."""
    tab = get_tableau(method)
    params = make_params(jax.random.PRNGKey(0))
    x0 = jax.random.normal(jax.random.PRNGKey(1), (4,))
    count = []

    def counting_field(x, t, p):
        count.append(1)
        return mlp_field(x, t, p)

    rk_solve_fixed(counting_field, tab, x0, 0.0, 1.0, 3, params)
    # scan traces the step body once: s trace-time calls, not s+1.
    assert len(count) == tab.s, (method, len(count), tab.s)

    # the adaptive path must still produce the estimate
    _, err = rk_step(mlp_field, tab, x0, jnp.asarray(0.0), jnp.asarray(0.1),
                     params, with_error=True)
    assert err is not None
    # and rk_step with_error=False must not
    _, err2 = rk_step(mlp_field, tab, x0, jnp.asarray(0.0), jnp.asarray(0.1),
                      params, with_error=False)
    assert err2 is None
