"""Pause/resume exactness of the explicit SolverState stepper (ISSUE 8).

The drivers are thin loops over ``AdaptiveStepper.advance`` /
``FixedStepper.advance``; this file pins the property that makes the
state machine worth having: a solve driven one ``advance`` at a time —
with the state flattened/unflattened and round-tripped through a
simulated save/restore (device -> host numpy -> device) mid-trajectory —
reproduces the UNINTERRUPTED solve BIT-FOR-BIT: final state, accepted
grids, stats, and the symplectic-adjoint gradients replayed from those
grids.  This is the contract the continuous-batching serve engine (and
any checkpointed long solve) stands on: pausing never perturbs the
numbers.

Cross-PROGRAM equality (a per-call jitted ``advance`` vs the fused
``lax.while_loop`` driver body) is additionally bitwise wherever XLA's
codegen is stable across those two compilation contexts — empirically
the lane-batched adaptive path and the fixed-grid path.  The scalar
adaptive path fuses differently inside a while body than standalone
(FMA/fusion choices on rank-0 ops), so there the driver comparison pins
integer stats exactly and floats to ~1 ulp-per-step accumulation; the
bit-for-bit pause/resume guarantee is unaffected (both sides of it run
the same executable).
"""
import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import AdaptiveConfig
from repro.core.rk import (rk_solve_adaptive, rk_solve_adaptive_batched,
                           rk_solve_fixed)
from repro.core.stepper import AdaptiveStepper, FixedStepper
from repro.core.symplectic import (_sym_bwd, _syma_bwd, _symab_bwd,
                                   odeint_symplectic,
                                   odeint_symplectic_adaptive,
                                   odeint_symplectic_adaptive_batched)
from repro.core.tableau import get_tableau

TAB = get_tableau("dopri5")
CFG = AdaptiveConfig(rtol=1e-6, atol=1e-8, max_steps=64, initial_step=0.05)
T0, T1 = 0.0, 1.0
DIM, B = 3, 4

PARAMS = {"w": jax.random.normal(jax.random.PRNGKey(0), (DIM, DIM)) * 0.5,
          "b": jax.random.normal(jax.random.PRNGKey(1), (DIM,)) * 0.1}
X0 = jax.random.normal(jax.random.PRNGKey(2), (DIM,))
X0_LANES = jax.random.normal(jax.random.PRNGKey(3), (B, DIM))
T1_LANES = jnp.linspace(0.6, 1.4, B)


def field(x, t, p):
    return jnp.tanh(x @ p["w"] + p["b"]) - 0.3 * x * jnp.sin(t)


def loss(x):
    return jnp.sum(jnp.sin(x) ** 2)


def tree_bits_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def tree_allclose(a, b, tol=1e-10):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(np.allclose(np.asarray(x), np.asarray(y), rtol=tol, atol=tol)
               for x, y in zip(la, lb))


def save_restore(state):
    """Simulated checkpoint: flatten, pull every leaf to host numpy (as a
    serializer would), rebuild the pytree from the host copies."""
    leaves, treedef = jax.tree_util.tree_flatten(state)
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    return jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(h) for h in host])


def drive(stepper, state, params, pause_after=None):
    """Run ``advance`` one jitted call at a time, optionally interrupting
    with a save/restore round-trip after ``pause_after`` attempted steps.
    Returns (final_state, n_calls)."""
    adv = jax.jit(stepper.advance)
    steps = 0
    while not bool(stepper.is_done(state)):
        state = adv(state, params)
        steps += 1
        if steps == pause_after:
            state = save_restore(state)
        assert steps < 10_000
    return state, steps


# ---------------------------------------------------------------------------
# adaptive, single trajectory
# ---------------------------------------------------------------------------

def test_adaptive_pause_resume_bit_exact():
    stepper = AdaptiveStepper(field, TAB, CFG)
    uninterrupted, n = drive(stepper, stepper.init_state(X0, T0, T1), PARAMS)
    assert n > 4                    # enough steps for a mid-flight pause
    paused, _ = drive(stepper, stepper.init_state(X0, T0, T1), PARAMS,
                      pause_after=3)
    assert tree_bits_equal(uninterrupted, paused)
    sol = stepper.finalize(paused)
    assert bool(sol.succeeded)
    assert int(sol.n_accepted) > 3


def test_adaptive_stepper_matches_driver():
    """The per-call advance drive vs the fused while_loop driver: stats and
    grids agree (floats to tight tolerance — XLA fuses rank-0 math
    differently inside a while body than in a standalone executable)."""
    one_shot = rk_solve_adaptive(field, TAB, X0, T0, T1, PARAMS, CFG)
    stepper = AdaptiveStepper(field, TAB, CFG)
    state, _ = drive(stepper, stepper.init_state(X0, T0, T1), PARAMS)
    sol = stepper.finalize(state)
    for f in ("n_accepted", "n_fevals", "n_attempts", "succeeded"):
        assert np.array_equal(np.asarray(getattr(one_shot, f)),
                              np.asarray(getattr(sol, f))), f
    for f in ("x_final", "xs", "ts", "hs", "h_final"):
        assert np.allclose(np.asarray(getattr(one_shot, f)),
                           np.asarray(getattr(sol, f)),
                           rtol=1e-9, atol=1e-9), f


def test_adaptive_pause_resume_gradients_bit_exact():
    stepper = AdaptiveStepper(field, TAB, CFG)

    def replay(state):
        sol = stepper.finalize(state)
        lam_N = jax.grad(loss)(sol.x_final)
        res = (sol.xs, sol.ts, sol.hs, sol.n_accepted, PARAMS,
               jnp.asarray(T0), jnp.asarray(T1))
        lam0, _, _, gtheta = _syma_bwd(field, TAB, CFG, "auto", res, lam_N)
        return lam0, gtheta

    uninterrupted, _ = drive(stepper, stepper.init_state(X0, T0, T1), PARAMS)
    paused, _ = drive(stepper, stepper.init_state(X0, T0, T1), PARAMS,
                      pause_after=3)
    g_full = replay(uninterrupted)
    g_paused = replay(paused)
    assert tree_bits_equal(g_full, g_paused)

    # and the replayed gradient agrees with end-to-end jax.grad through
    # the driver (same checkpoints up to the while-body fusion ulps)
    g_one = jax.grad(
        lambda x0, p: loss(odeint_symplectic_adaptive(
            field, TAB, CFG, "auto", x0, T0, T1, p)),
        argnums=(0, 1))(X0, PARAMS)
    assert tree_allclose(g_one[0], g_paused[0], tol=1e-8)
    assert tree_allclose(g_one[1], g_paused[1], tol=1e-8)


def test_tolerances_as_data_bit_match_closed_floats():
    """Per-solve rtol/atol ARRAYS (the serve engine's tolerances-as-data
    path) must reproduce the closed-Python-float solve exactly — grids,
    stats, and controller trajectory — through the same advance
    executable."""
    stepper = AdaptiveStepper(field, TAB, CFG)
    closed, _ = drive(stepper, stepper.init_state(X0, T0, T1), PARAMS)
    as_data = stepper.init_state(X0, T0, T1, rtol=CFG.rtol, atol=CFG.atol)
    assert as_data.rtol is not None
    adv = jax.jit(stepper.advance)
    while not bool(stepper.is_done(as_data)):
        as_data = adv(as_data, PARAMS)
    # rtol/atol ride along in the state; compare everything else
    drop = lambda s: s._replace(rtol=None, atol=None)
    assert tree_bits_equal(drop(closed), drop(as_data))


def test_advance_past_done_is_identity():
    """Driving ``advance`` beyond completion must not move the state — the
    serve engine relies on this to keep finished/free lanes frozen inside
    a running batch."""
    stepper = AdaptiveStepper(field, TAB, CFG)
    state = stepper.run(stepper.init_state(X0, T0, T1), PARAMS)
    assert bool(stepper.is_done(state))
    again = stepper.advance(state, PARAMS)
    assert tree_bits_equal(state, again)


# ---------------------------------------------------------------------------
# adaptive, lane-batched (the serve engine's path: cross-program bitwise)
# ---------------------------------------------------------------------------

def test_batched_pause_resume_bit_exact():
    one_shot = rk_solve_adaptive_batched(field, TAB, X0_LANES, T0, T1_LANES,
                                         PARAMS, CFG)
    stepper = AdaptiveStepper(field, TAB, CFG)
    state, _ = drive(stepper,
                     stepper.init_state(X0_LANES, T0, T1_LANES, lanes=B),
                     PARAMS, pause_after=3)
    resumed = stepper.finalize(state)
    assert tree_bits_equal(one_shot._asdict(), resumed._asdict())
    assert bool(jnp.all(resumed.succeeded))
    # heterogeneous horizons: lanes finish at different step counts, so the
    # pause caught some lanes mid-flight and others done
    assert len(set(np.asarray(resumed.n_accepted).tolist())) > 1


def test_batched_pause_resume_gradients_bit_exact():
    g_one = jax.grad(
        lambda x0, p: loss(odeint_symplectic_adaptive_batched(
            field, TAB, CFG, "auto", x0, T0, T1_LANES, p)),
        argnums=(0, 1))(X0_LANES, PARAMS)

    stepper = AdaptiveStepper(field, TAB, CFG)
    state, _ = drive(stepper,
                     stepper.init_state(X0_LANES, T0, T1_LANES, lanes=B),
                     PARAMS, pause_after=3)
    sol = stepper.finalize(state)
    lam_N = jax.grad(loss)(sol.x_final)
    res = (sol.xs, sol.ts, sol.hs, sol.n_accepted, PARAMS,
           jnp.asarray(T0), jnp.asarray(T1_LANES))
    lam0, _, _, gtheta = _symab_bwd(field, TAB, CFG, "auto", res, lam_N)
    assert tree_bits_equal(g_one[0], lam0)
    assert tree_bits_equal(g_one[1], gtheta)


# ---------------------------------------------------------------------------
# fixed grid (cross-program bitwise)
# ---------------------------------------------------------------------------

N_STEPS = 8


def test_fixed_pause_resume_bit_exact():
    one_shot = rk_solve_fixed(field, TAB, X0, T0, T1, N_STEPS, PARAMS)
    stepper = FixedStepper(field, TAB, N_STEPS)
    state = stepper.init_state(X0, T0, T1)
    adv = jax.jit(stepper.advance)
    for n in range(N_STEPS):
        assert not bool(stepper.is_done(state))
        state = adv(state, PARAMS)
        if n == N_STEPS // 2:
            state = save_restore(state)
    assert bool(stepper.is_done(state))
    resumed = stepper.finalize(state)
    assert tree_bits_equal(one_shot._asdict(), resumed._asdict())


def test_fixed_pause_resume_gradients_bit_exact():
    g_one = jax.grad(
        lambda x0, p: loss(odeint_symplectic(
            field, TAB, N_STEPS, "auto", x0, T0, T1, p)),
        argnums=(0, 1))(X0, PARAMS)

    stepper = FixedStepper(field, TAB, N_STEPS)
    state = stepper.init_state(X0, T0, T1)
    adv = jax.jit(stepper.advance)
    for n in range(N_STEPS):
        state = adv(state, PARAMS)
        if n == 2:
            state = save_restore(state)
    sol = stepper.finalize(state)
    lam_N = jax.grad(loss)(sol.x_final)
    res = (sol.xs, sol.ts, sol.h, PARAMS, jnp.asarray(T0), jnp.asarray(T1))
    lam0, _, _, gtheta = _sym_bwd(field, TAB, N_STEPS, "auto", res, lam_N)
    assert tree_bits_equal(g_one[0], lam0)
    assert tree_bits_equal(g_one[1], gtheta)
