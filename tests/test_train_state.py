"""The ``train.TrainState`` checkpoint contract and the train-step factory.

Pins (ISSUE 10):
  * ``TrainState`` is one registered pytree (jit/flatten round-trips) with
    mapping-style access for legacy dict-state callers;
  * one ``train_step`` advances EVERY contract field: optimizer + LR
    schedule step, rng stream, data cursor, static solver counters
    (``node_solver_counts``);
  * the grad-accumulation path (microbatches=k) matches the unaccumulated
    step to float tolerance;
  * the int8 compression error-feedback residual survives a checkpoint
    save/restore — continued training from the restored state is BITWISE
    identical to continuing from the live state;
  * ``parallel.state_specs`` mirrors a ``TrainState`` into a TrainState of
    PartitionSpecs (host scalars replicated), usable as jit in_shardings.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_arch
from repro.configs.base import NodeConfig
from repro.data.tokens import synthetic_lm_batch
from repro.optim import CompressionConfig
from repro.parallel import state_specs
from repro.runtime import Checkpointer
from repro.train import (TrainConfig, TrainState, init_train_state,
                         make_train_step, node_solver_counts)


def _batch(step=0, batch=2, seq=16):
    arch = get_smoke_arch("qwen3-0.6b")
    return synthetic_lm_batch(step, batch, seq + 1, arch.vocab)


def _leaves(t):
    return jax.tree_util.tree_leaves(t)


def test_train_state_is_pytree_with_mapping_access():
    arch = get_smoke_arch("qwen3-0.6b")
    state = init_train_state(jax.random.PRNGKey(0), arch, TrainConfig())
    assert isinstance(state, TrainState)
    assert state["params"] is state.params
    assert state["opt"] is state.opt
    # compression off => no compress_err entry, like the legacy dict state
    assert "compress_err" not in state
    assert state.get("compress_err") is None
    with pytest.raises(KeyError):
        state["compress_err"]
    assert set(state.keys()) == {"params", "opt", "rng", "data_step",
                                 "solver_stats"}

    leaves, treedef = jax.tree_util.tree_flatten(state)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(rebuilt, TrainState)
    out = jax.jit(lambda s: s)(state)
    assert isinstance(out, TrainState)
    for a, b in zip(_leaves(state), _leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_step_advances_every_contract_field():
    arch = get_smoke_arch("qwen3-0.6b").with_(
        node=NodeConfig(mode="node", method="euler",
                        grad_mode="symplectic"))
    tcfg = TrainConfig()
    state = init_train_state(jax.random.PRNGKey(0), arch, tcfg)
    assert int(state.data_step) == 0
    assert int(state.solver_stats["n_steps"]) == 0

    step_fn = jax.jit(make_train_step(arch, tcfg))
    batch = _batch(0)
    s1, m1 = step_fn(state, batch)
    s2, m2 = step_fn(s1, _batch(1))

    assert int(s1.data_step) == 1 and int(s2.data_step) == 2
    assert int(s2.opt["step"]) == 2          # the LR-schedule step
    # the rng stream advances every step (stochastic layers ride the
    # contract without changing the checkpoint format)
    assert not np.array_equal(np.asarray(state.rng), np.asarray(s1.rng))
    assert not np.array_equal(np.asarray(s1.rng), np.asarray(s2.rng))
    # static solve counters: fixed-grid NODE cost is a config property
    n_steps, n_fevals = node_solver_counts(arch)
    assert n_steps > 0 and n_fevals >= n_steps
    assert int(s2.solver_stats["n_steps"]) == 2 * n_steps
    assert int(s2.solver_stats["n_fevals"]) == 2 * n_fevals
    # and params actually moved
    assert float(m2["loss"]) != float(m1["loss"])


def test_grad_accumulation_matches_unaccumulated():
    arch = get_smoke_arch("qwen3-0.6b")
    state = init_train_state(jax.random.PRNGKey(0), arch, TrainConfig())
    batch = _batch(0, batch=4)

    s_full, m_full = jax.jit(
        make_train_step(arch, TrainConfig(microbatches=1)))(state, batch)
    s_acc, m_acc = jax.jit(
        make_train_step(arch, TrainConfig(microbatches=2)))(state, batch)

    np.testing.assert_allclose(float(m_acc["loss"]), float(m_full["loss"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(m_acc["grad_norm"]),
                               float(m_full["grad_norm"]), rtol=1e-4)
    for a, b in zip(_leaves(s_acc.params), _leaves(s_full.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


def test_compress_error_feedback_survives_checkpoint(tmp_path):
    arch = get_smoke_arch("qwen3-0.6b")
    tcfg = TrainConfig(compression=CompressionConfig(mode="int8"))
    state = init_train_state(jax.random.PRNGKey(0), arch, tcfg)
    assert "compress_err" in state and state.compress_err is not None

    step_fn = jax.jit(make_train_step(arch, tcfg))
    s1, _ = step_fn(state, _batch(0))
    # quantization left a nonzero residual to carry into the next step
    assert any(np.any(np.asarray(l))
               for l in _leaves(s1.compress_err))

    ck = Checkpointer(str(tmp_path))
    ck.save(1, s1)
    like = init_train_state(jax.random.PRNGKey(7), arch, tcfg)
    restored, step = ck.restore(like)
    assert step == 1

    # continuing from the restored state is bitwise identical — the
    # residual is part of the convergence argument, so it must survive
    s2_live, m_live = step_fn(s1, _batch(1))
    s2_rest, m_rest = step_fn(restored, _batch(1))
    assert float(m_live["loss"]) == float(m_rest["loss"])
    for a, b in zip(_leaves(s2_live), _leaves(s2_rest)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class _FakeMesh:
    """Duck-typed mesh (the spec layer reads only .shape/.axis_names)."""
    shape = {"data": 2, "model": 2}
    axis_names = ("data", "model")


def test_state_specs_mirrors_train_state():
    arch = get_smoke_arch("qwen3-0.6b")
    state = init_train_state(jax.random.PRNGKey(0), arch, TrainConfig())
    specs = state_specs(state, _FakeMesh())
    assert isinstance(specs, TrainState)

    is_p = lambda x: isinstance(x, P)  # noqa: E731
    # host-scalar fields replicated
    assert specs.data_step == P()
    assert all(s == P() for s in
               jax.tree_util.tree_leaves(specs.solver_stats, is_leaf=is_p))
    assert all(e is None for e in specs.rng)
    # something in params is model-sharded (smoke embed is (128, 32))
    axes = {e for s in jax.tree_util.tree_leaves(specs.params,
                                                 is_leaf=is_p)
            for e in s if e is not None}
    assert "model" in axes
    # treedefs line up, so the spec tree works as jit in_shardings
    assert (jax.tree_util.tree_structure(specs, is_leaf=is_p)
            == jax.tree_util.tree_structure(state))
