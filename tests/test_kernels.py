"""Per-kernel interpret-mode validation against the pure-jnp oracles.

Shape/dtype sweeps (parametrized + hypothesis) with assert_allclose per the
deliverable spec.  All Pallas execution uses interpret=True (CPU container).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal container: run fixed examples instead
    from hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.butcher_combine import butcher_combine_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rms_norm_pallas

TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


# --- butcher_combine ---------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,s", [((64,), 4), ((33, 7), 7),
                                     ((4, 128, 128), 13), ((1,), 1),
                                     ((1024,), 6)])
def test_butcher_combine_matches_ref(shape, s, dtype):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    x = _rand(keys[0], shape, dtype)
    ks = _rand(keys[1], (s,) + shape, dtype)
    coefs = jax.random.normal(keys[2], (s,), dtype=jnp.float32)
    h = jnp.float32(0.125)
    got = butcher_combine_pallas(x, ks, coefs, h, interpret=True)
    want = ref.butcher_combine_ref(x, ks, coefs, h)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 700), s=st.integers(1, 13),
       block_rows=st.sampled_from([8, 64, 256]))
def test_butcher_combine_property(n, s, block_rows):
    keys = jax.random.split(jax.random.PRNGKey(n * 31 + s), 3)
    x = _rand(keys[0], (n,), jnp.float32)
    ks = _rand(keys[1], (s, n), jnp.float32)
    coefs = jax.random.normal(keys[2], (s,))
    h = jnp.float32(0.01)
    got = butcher_combine_pallas(x, ks, coefs, h, block_rows=block_rows,
                                 interpret=True)
    want = ref.butcher_combine_ref(x, ks, coefs, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# --- rms_norm ----------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 256), (3, 5, 128), (1, 1024),
                                   (130, 384)])
@pytest.mark.parametrize("with_residual", [False, True])
def test_rms_norm_matches_ref(shape, dtype, with_residual):
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    x = _rand(keys[0], shape, dtype)
    w = _rand(keys[1], (shape[-1],), jnp.float32)
    res = _rand(keys[2], shape, dtype) if with_residual else None
    got = rms_norm_pallas(x, w, res, interpret=True)
    want = ref.rms_norm_ref(x, w, res)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@settings(max_examples=15, deadline=None)
@given(rows=st.integers(1, 300), d=st.sampled_from([128, 256, 512]),
       block_rows=st.sampled_from([8, 128]))
def test_rms_norm_property(rows, d, block_rows):
    keys = jax.random.split(jax.random.PRNGKey(rows * 7 + d), 2)
    x = _rand(keys[0], (rows, d), jnp.float32)
    w = _rand(keys[1], (d,), jnp.float32)
    got = rms_norm_pallas(x, w, block_rows=block_rows, interpret=True)
    want = ref.rms_norm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# --- flash attention ---------------------------------------------------------

ATTN_CASES = [
    # (B, H, Hkv, Sq, Sk, D, causal, window, q_offset)
    (1, 4, 4, 128, 128, 64, True, None, 0),     # MHA causal
    (2, 8, 2, 256, 256, 64, True, None, 0),     # GQA causal
    (1, 4, 1, 128, 128, 128, True, 64, 0),      # MQA + sliding window
    (1, 4, 2, 100, 100, 64, True, None, 0),     # ragged (padding path)
    (2, 8, 4, 1, 512, 64, True, None, 511),     # decode: 1 query vs cache
    (1, 4, 4, 64, 256, 64, True, None, 192),    # chunked prefill offset
    (1, 4, 4, 128, 128, 64, False, None, 0),    # non-causal (encoder)
    (1, 16, 8, 1, 300, 64, True, 128, 299),     # decode + SWA, ragged cache
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_matches_ref(case, dtype):
    B, H, Hkv, Sq, Sk, D, causal, window, q_offset = case
    keys = jax.random.split(jax.random.PRNGKey(42), 3)
    q = _rand(keys[0], (B, H, Sq, D), dtype)
    k = _rand(keys[1], (B, Hkv, Sk, D), dtype)
    v = _rand(keys[2], (B, Hkv, Sk, D), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, block_q=64, block_k=64,
                                 interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_offset)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("block_q,block_k", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_shape_invariance(block_q, block_k):
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rand(keys[0], (1, 4, 200, 64), jnp.float32)
    k = _rand(keys[1], (1, 2, 200, 64), jnp.float32)
    v = _rand(keys[2], (1, 2, 200, 64), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=block_q,
                                 block_k=block_k, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_swa_equals_full_when_window_covers():
    """window >= Sk must equal unwindowed attention."""
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    q = _rand(keys[0], (1, 2, 64, 32), jnp.float32)
    k = _rand(keys[1], (1, 2, 64, 32), jnp.float32)
    v = _rand(keys[2], (1, 2, 64, 32), jnp.float32)
    a = flash_attention_pallas(q, k, v, causal=True, window=4096,
                               block_q=32, block_k=32, interpret=True)
    b = flash_attention_pallas(q, k, v, causal=True, window=None,
                               block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                               atol=1e-6)
