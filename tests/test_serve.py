"""The continuous-batching solve engine (repro.serve) — ISSUE 8.

Pins the three claims docs/serving.md makes:

* EQUIVALENCE — every request served out of the shared slot state gets
  the same answer it would get served ALONE (any bucket size), bit for
  bit: lane masking, bucket growth, tolerances-as-data, and mid-flight
  neighbors must all be invisible in the numbers.  Against the scalar
  ``rk_solve_adaptive`` driver the controller trajectory is pinned
  exactly (identical accept/reject sequence: n_accepted, n_fevals) and
  the floats to tight tolerance — the lane-batched advance and the
  rank-0 while-body fuse differently in XLA (see tests/test_stepper.py).
* CONTINUOUS BATCHING — requests really are inserted into a RUNNING
  batch (not phase-locked cohorts), and the slot state grows through the
  configured buckets as demand rises.
* IN-PLACE UPDATE — the AOT advance actually donates the slot state:
  the previous step's buffers are consumed, not copied.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import AdaptiveConfig
from repro.core.rk import rk_solve_adaptive
from repro.core.tableau import get_tableau
from repro.serve import (EngineConfig, Request, SolveEngine,
                         naive_sequential_solve, synthetic_stream)

TAB = get_tableau("dopri5")
CFG = AdaptiveConfig(rtol=1e-6, atol=1e-8, max_steps=128, initial_step=0.05)
DIM = 3

PARAMS = {"w": jax.random.normal(jax.random.PRNGKey(0), (DIM, DIM)) * 0.5,
          "b": jax.random.normal(jax.random.PRNGKey(1), (DIM,)) * 0.1}


def field(x, t, p):
    return jnp.tanh(x @ p["w"] + p["b"]) - 0.3 * x * jnp.sin(t)


def make_engine(buckets=(2, 4), check_every=1):
    return SolveEngine(field, TAB, CFG, PARAMS,
                       x0_template=jnp.zeros((DIM,)),
                       engine_cfg=EngineConfig(buckets=buckets,
                                               check_every=check_every))


def solo(req: Request, buckets=(2,)):
    """The bitwise reference: the same request served alone."""
    return make_engine(buckets=buckets).run([req])[0]


def driver_reference(req: Request):
    cfg = dataclasses.replace(CFG, rtol=req.rtol, atol=req.atol)
    return rk_solve_adaptive(field, TAB, req.x0, req.t0, req.t1, PARAMS, cfg)


def check_request(results, rid, req):
    got = results[rid]
    alone = solo(req)
    assert got.succeeded and alone.succeeded
    assert np.array_equal(np.asarray(got.x_final),
                          np.asarray(alone.x_final)), rid
    assert (got.n_accepted, got.n_fevals, got.n_attempts) == \
        (alone.n_accepted, alone.n_fevals, alone.n_attempts), rid
    ref = driver_reference(req)
    assert got.n_accepted == int(ref.n_accepted), rid
    assert got.n_fevals == int(ref.n_fevals), rid
    assert np.allclose(np.asarray(got.x_final), np.asarray(ref.x_final),
                       rtol=1e-9, atol=1e-9), rid


def test_engine_matches_single_solves():
    reqs = synthetic_stream(6, DIM, seed=7)
    engine = make_engine()
    results = engine.run(reqs)
    assert sorted(results) == list(range(6))
    for rid, req in enumerate(reqs):
        check_request(results, rid, req)
    # with 6 requests and a 2-lane starting bucket the engine must have
    # inserted into a running batch (continuous batching, not cohorts)
    assert engine.stats["inserted_while_running"] > 0


def test_insertion_into_running_batch_single_bucket():
    """A fixed 2-lane state serving 5 requests forces evict-then-insert
    against live lanes; late arrivals join mid-flight neighbours."""
    reqs = synthetic_stream(5, DIM, seed=11)
    engine = make_engine(buckets=(2,))
    results = engine.run(reqs)
    assert len(results) == 5
    assert engine.stats["lanes"] == 2
    assert engine.stats["inserted_while_running"] >= 3
    for rid, req in enumerate(reqs):
        check_request(results, rid, req)


def test_bucket_growth_under_demand():
    reqs = synthetic_stream(6, DIM, seed=3)
    engine = make_engine(buckets=(2, 4, 8))
    assert engine.stats["lanes"] == 2
    results = engine.run(reqs)
    assert engine.stats["lanes"] == 8      # demand 6 -> next bucket up
    assert len(results) == 6
    for rid, req in enumerate(reqs):
        check_request(results, rid, req)


def test_advance_donates_slot_state():
    engine = make_engine(buckets=(2,))
    engine.submit(synthetic_stream(1, DIM, seed=5)[0])
    engine._fill()
    before = engine._state
    engine._state = engine._advance[engine._lanes](before, engine.params)
    assert before.t.is_deleted()           # buffer consumed, not copied
    assert before.ts.is_deleted()


def test_submit_rejects_mismatched_pytree():
    engine = make_engine()
    bad = Request(x0={"x": jnp.zeros((DIM,))}, t0=0.0, t1=1.0,
                  rtol=1e-6, atol=1e-8)
    with pytest.raises(ValueError, match="pytree structure"):
        engine.submit(bad)


def test_engine_config_validation():
    with pytest.raises(ValueError, match="strictly increasing"):
        EngineConfig(buckets=(4, 4, 8))
    with pytest.raises(ValueError, match="check_every"):
        EngineConfig(check_every=0)


def test_naive_baseline_agrees_with_engine():
    reqs = synthetic_stream(4, DIM, seed=9)
    engine = make_engine()
    results = engine.run(reqs)
    naive, lat = naive_sequential_solve(field, TAB, CFG, PARAMS, reqs)
    assert len(lat) == 4
    for rid, sol in enumerate(naive):
        assert results[rid].n_accepted == int(sol.n_accepted)
        assert results[rid].n_fevals == int(sol.n_fevals)
        assert np.allclose(np.asarray(results[rid].x_final),
                           np.asarray(sol.x_final),
                           rtol=1e-9, atol=1e-9), rid
