"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned arch: instantiate the SMOKE config, run one forward and
one full train step (loss + grads + AdamW), assert output shapes and
finiteness; run prefill + one decode step for the serving families.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_arch
from repro.configs.base import NodeConfig
from repro.data.tokens import synthetic_lm_batch
from repro.train import (TrainConfig, init_train_state, make_decode_step,
                         make_prefill_step, make_train_step)

B, S = 2, 16

# compile-heavy architectures (multi-layer units / very wide smoke configs):
# their smoke tests dominate suite wall time, so the CI fast lane skips them
# (-m "not slow"); the full-suite job still runs every architecture.
HEAVY_ARCHS = {"jamba-v0.1-52b", "xlstm-1.3b", "deepseek-v2-lite-16b",
               "mixtral-8x7b"}
ARCH_PARAMS = [pytest.param(a, marks=pytest.mark.slow)
               if a in HEAVY_ARCHS else a for a in ARCH_IDS]


def _batch(arch):
    b = synthetic_lm_batch(0, B, S + 1, arch.vocab)
    batch = {"tokens": jnp.asarray(b["tokens"]),
             "labels": jnp.asarray(b["labels"])}
    if arch.encdec:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, S, arch.d_frontend))
    if arch.frontend == "patch":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, 4, arch.d_frontend))
    return batch


def _finite(tree):
    return all(bool(jnp.all(jnp.isfinite(l)))
               for l in jax.tree_util.tree_leaves(tree)
               if jnp.issubdtype(l.dtype, jnp.floating))


@pytest.mark.parametrize("arch_id", ARCH_PARAMS)
def test_train_step_smoke(arch_id):
    arch = get_smoke_arch(arch_id)
    tcfg = TrainConfig(lr=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), arch, tcfg)
    step = jax.jit(make_train_step(arch, tcfg))
    batch = _batch(arch)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch_id
    assert _finite(state["params"]), arch_id
    # loss decreases over a few steps (sanity that gradients are useful)
    first = float(metrics["loss"])
    for _ in range(3):
        state, metrics = step(state, batch)
    assert float(metrics["loss"]) < first, arch_id


@pytest.mark.parametrize("arch_id", ARCH_PARAMS)
def test_prefill_decode_smoke(arch_id):
    arch = get_smoke_arch(arch_id)
    tcfg = TrainConfig()
    state = init_train_state(jax.random.PRNGKey(0), arch, tcfg)
    params = state["params"]
    max_len = S + 8
    prefill = jax.jit(make_prefill_step(arch, B, max_len))
    decode = jax.jit(make_decode_step(arch))
    batch = _batch(arch)
    logits, caches = prefill(params, batch)
    assert logits.shape == (B, 1, arch.vocab), arch_id
    assert _finite(logits), arch_id
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    # decode position: SSM/xLSTM states are positionless; attention caches
    # append at S (or S + n_patches for the VLM prefix).
    pos = jnp.int32(S + (4 if arch.frontend == "patch" else 0))
    logits2, caches = decode(params, caches, tok, pos)
    assert logits2.shape == (B, 1, arch.vocab), arch_id
    assert _finite(logits2), arch_id


@pytest.mark.parametrize("arch_id", [
    "qwen3-0.6b",
    pytest.param("mixtral-8x7b", marks=pytest.mark.slow),
    pytest.param("xlstm-1.3b", marks=pytest.mark.slow)])
def test_node_mode_smoke(arch_id):
    """The paper's technique on a reduced config of each family kind."""
    arch = get_smoke_arch(arch_id).with_(
        node=NodeConfig(mode="node", method="euler",
                        grad_mode="symplectic"))
    tcfg = TrainConfig(lr=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), arch, tcfg)
    step = jax.jit(make_train_step(arch, tcfg))
    state, metrics = step(state, _batch(arch))
    assert np.isfinite(float(metrics["loss"])), arch_id
    assert _finite(state["params"]), arch_id
