"""repro.parallel: mesh-sharded masked-batch solving.

Acceptance criteria pinned here (ISSUE 9):
  * ``solve(..., batch_axis=0, mesh=...)`` on (4,) and (2, 2) data meshes
    matches the single-device masked batch solve — each shard's lane block
    is BITWISE identical to a single-device solve of that block (values,
    per-lane stats, accepted grids, h carries), and the gathered result
    matches the full-width batch exactly on integer stats and to f64
    rounding on floats (the full-width grids themselves are batch-width-
    dependent XLA codegen, the test_batch.py precedent);
  * sharded gradients match unsharded ones to <= 1e-12 (f64) for the
    symplectic AND continuous adjoint, fixed AND adaptive stepping;
  * the collective-count rule proves the backward jaxpr all-reduces
    exactly the theta cotangents (one psum per param leaf) and nothing
    else, and the forward is collective-free;
  * ``batch_specs`` falls back to a divisible PREFIX of ("pod", "data")
    with a warning instead of silently replicating (B=6 on a (2, 2)
    mesh shards 2-way over "pod").

The spec/rule layer only reads ``mesh.shape`` / ``mesh.axis_names``, so it
is tested in-process against a duck-typed stand-in; everything needing
real multi-device execution goes through the ``run_sharded`` subprocess
fixture (tests/conftest.py) because the forced host-device flag must be
set before jax initializes.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax.sharding import PartitionSpec as P

from repro.core import AdaptiveConfig, solve
from repro.core.stepper import AdaptiveStepper
from repro.core.tableau import get_tableau
from repro.launch.mesh import make_debug_mesh, make_lane_mesh
from repro.parallel import (batch_specs, batched_solution_specs, lane_axes,
                            lane_spec, make_sharder, param_specs,
                            shard_count, solver_state_specs, state_specs,
                            with_shard_load_stats)
from repro.serve.engine import EngineConfig


class _FakeMesh:
    """Duck-typed mesh: the spec layer reads only .shape / .axis_names, so
    divisibility and path rules are testable without real devices."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


# ---------------------------------------------------------------------------
# lane_axes: the divisible-prefix rule
# ---------------------------------------------------------------------------

def test_lane_axes_divisible_prefix():
    mesh = _FakeMesh(pod=2, data=2)
    assert lane_axes(mesh, 8) == ("pod", "data")
    with pytest.warns(UserWarning, match="divisible prefix"):
        assert lane_axes(mesh, 6) == ("pod",)
    with pytest.warns(UserWarning, match="replicated"):
        assert lane_axes(mesh, 5) == ()
    with pytest.raises(ValueError, match="Pad the batch"):
        lane_axes(mesh, 5, require=True)
    assert lane_axes(_FakeMesh(data=4), 8) == ("data",)
    assert lane_axes(_FakeMesh(data=4, model=2), 8) == ("data",)
    # a mesh with NO data axis can never satisfy require=True
    assert lane_axes(_FakeMesh(model=2), 8) == ()
    with pytest.raises(ValueError, match="none of the data axes"):
        lane_axes(_FakeMesh(model=2), 8, require=True)
    assert shard_count(mesh, ("pod", "data")) == 4
    assert shard_count(mesh, ()) == 1


def test_batch_specs_prefix_fallback():
    mesh = _FakeMesh(pod=2, data=2)
    batch = {"x": np.zeros((6, 3)), "y": np.zeros((8,)),
             "s": np.zeros(())}
    with pytest.warns(UserWarning, match="divisible prefix"):
        specs = batch_specs(batch, mesh)
    # B=6 on the (2, 2) mesh: 2-way over "pod", NOT silently replicated
    assert specs["x"] == P(("pod",), None)
    assert specs["y"] == P(("pod", "data"))
    assert specs["s"] == P()
    # nothing divides 5: replicate (still warned)
    with pytest.warns(UserWarning):
        specs5 = batch_specs({"x": np.zeros((5, 2))}, mesh)
    assert specs5["x"] == P(None, None)


# ---------------------------------------------------------------------------
# dormant spec layer: param/state path rules, make_sharder
# ---------------------------------------------------------------------------

def test_param_specs_path_rules():
    mesh = _FakeMesh(data=2, model=2)
    params = {"blk": {"wq": np.zeros((8, 8)), "wo": np.zeros((8, 8)),
                      "b1": np.zeros((8,)), "scale": np.zeros(())},
              "narrow": {"wq": np.zeros((8, 5))},
              "moe": {"wg": np.zeros((4, 8, 8))},
              "unit": {"wq": np.zeros((3, 8, 8))}}
    specs = param_specs(params, mesh)
    assert specs["blk"]["wq"] == P(None, "model")       # column-parallel
    assert specs["blk"]["wo"] == P("model", None)       # row-parallel
    assert specs["blk"]["b1"] == P(None)                # replicated
    assert specs["blk"]["scale"] == P()
    # non-divisible OUT dim (5 % 2): the model assignment is dropped
    assert specs["narrow"]["wq"] == P(None, None)
    # expert bank, TP-in-expert by default; EP shards the expert dim
    assert specs["moe"]["wg"] == P(None, None, "model")
    assert param_specs(params, mesh, ep=True)["moe"]["wg"] \
        == P("model", None, None)
    # vmap-stacked layer dim is never sharded
    assert specs["unit"]["wq"] == P(None, None, "model")
    # a data-only mesh has no "model" axis: everything replicates
    flat = jax.tree_util.tree_leaves(
        param_specs(params, _FakeMesh(data=4)))
    assert all(s == P() for s in flat)


def test_state_specs_zero1():
    mesh = _FakeMesh(data=2, model=2)
    p = {"wq": np.zeros((8, 8)), "tiny": np.zeros((3,))}
    state = {"params": p,
             "opt": {"step": np.zeros(()),
                     "m": {"wq": np.zeros((8, 8)),
                           "tiny": np.zeros((3,))}}}
    specs = state_specs(state, mesh)
    assert specs["params"]["wq"] == P(None, "model")
    assert specs["opt"]["step"] == P()
    # ZeRO-1: the m leaf takes "data" on the first unsharded divisible dim
    assert specs["opt"]["m"]["wq"] == P("data", "model")
    # ...but never a non-divisible one (3 % 2)
    assert specs["opt"]["m"]["tiny"] == P(None)
    assert state_specs(state, mesh, zero1=False)["opt"]["m"]["wq"] \
        == P(None, "model")


def test_make_sharder_none_mesh_is_identity():
    shard = make_sharder(None)
    x = jnp.ones((4, 4))
    assert shard(x, ("batch", "ffn")) is x


# ---------------------------------------------------------------------------
# solve-facing spec builders
# ---------------------------------------------------------------------------

def test_batched_solution_specs_layout():
    specs = batched_solution_specs(("data",))
    assert specs.x_final == P(("data",))
    assert specs.n_accepted == P(("data",))
    # step-major checkpoint stacks carry lanes on axis 1
    assert specs.ts == P(None, ("data",))
    assert specs.hs == P(None, ("data",))
    assert lane_spec((), 0) == P()
    assert lane_spec(("pod", "data"), 1) == P(None, ("pod", "data"))


def test_solver_state_specs_shape_aware():
    def field(x, t, p):
        return -x
    stepper = AdaptiveStepper(field, get_tableau("bosh3"),
                              AdaptiveConfig(max_steps=4), "jnp")
    batched = stepper.init_state(jnp.zeros((4, 2)), 0.0, 1.0, lanes=4,
                                 rtol=1e-6, atol=1e-8)
    specs = solver_state_specs(batched, ("data",))
    # the engine's horizons are PER-LANE (B,) arrays: they shard too
    assert specs.t0 == P(("data",))
    assert specs.rtol == P(("data",))
    assert specs.ts == P(None, ("data",))
    assert jax.tree_util.tree_leaves(specs.x)[0] == P(("data",))
    single = stepper.init_state(jnp.zeros((2,)), 0.0, 1.0)
    specs1 = solver_state_specs(single, ("data",))
    assert specs1.t0 == P()
    assert specs1.ts == P()          # (max_steps,) buffer: no lane axis
    assert specs1.rtol is None


def test_with_shard_load_stats():
    stats = with_shard_load_stats(
        {"n_steps": jnp.array([1, 2, 3, 5], jnp.int32)}, 2)
    np.testing.assert_array_equal(np.asarray(stats["shard_steps"]), [3, 8])
    assert float(stats["load_imbalance"]) == pytest.approx(8 / 5.5)
    assert stats["n_steps"].shape == (4,)


# ---------------------------------------------------------------------------
# api validation + device-count ergonomics
# ---------------------------------------------------------------------------

def _field(x, t, p):
    return jnp.tanh(x @ p["w"])


def test_solve_mesh_validation():
    params = {"w": jnp.eye(2) * 0.1}
    x0 = jnp.ones((4, 2))
    with pytest.raises(ValueError, match="batch_axis=0"):
        solve(_field, x0[0], params, stepping=AdaptiveConfig(max_steps=8),
              mesh=_FakeMesh(data=4))
    with pytest.raises(ValueError, match="requires mesh="):
        solve(_field, x0, params, stepping=AdaptiveConfig(max_steps=8),
              batch_axis=0, sharding="auto")


def test_engine_config_mesh_bucket_validation():
    mesh = _FakeMesh(data=4)
    with pytest.raises(ValueError, match="divisible by 4"):
        EngineConfig(buckets=(4, 6), mesh=mesh)
    EngineConfig(buckets=(4, 8), mesh=mesh)     # whole shards: fine


def test_debug_mesh_names_the_flag():
    need = len(jax.devices()) + 1
    with pytest.raises(RuntimeError,
                       match="xla_force_host_platform_device_count"):
        make_debug_mesh(need, 1)
    with pytest.raises(RuntimeError,
                       match="xla_force_host_platform_device_count"):
        make_lane_mesh((need,))


# ---------------------------------------------------------------------------
# the communication contract, jaxpr-level (1-way mesh: shard_map emits the
# same structure as an N-way one, so this runs in the single-device suite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy,stepping", [("symplectic", "adaptive"),
                                               ("adjoint", "adaptive"),
                                               ("symplectic", "fixed")])
def test_collective_contract(strategy, stepping):
    from repro.analysis.cases import sharded_solve_probe
    from repro.analysis.rules import collective_findings
    from repro.analysis.traversal import collective_eqns
    probe = sharded_solve_probe(strategy, stepping)
    assert collective_findings(probe["value"], "t", kind="value") == []
    assert collective_findings(probe["grad"], "t", kind="grad",
                               param_shapes=probe["param_shapes"]) == []
    # exactly one real psum per theta leaf, nothing else
    colls = collective_eqns(probe["grad"].jaxpr)
    assert sorted(s for n, _, shapes in colls for s in shapes) \
        == sorted(tuple(s) for s in probe["param_shapes"])
    assert all(n == "psum" for n, _, _ in colls)
    # and the rule actually bites when the expectation is wrong
    bad = collective_findings(probe["grad"], "t", kind="grad",
                              param_shapes=probe["param_shapes"] + [(7,)])
    assert bad and bad[0].severity == "error"


# ---------------------------------------------------------------------------
# multi-device numerics (subprocess: 8 forced host devices)
# ---------------------------------------------------------------------------

_PREAMBLE = r"""
import jax, jax.numpy as jnp, numpy as np
jax.config.update("jax_enable_x64", True)
from repro.core import AdaptiveConfig, SaveAt, solve
from repro.launch.mesh import make_lane_mesh

B, dim, hidden = 8, 4, 8
k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
params = {"w1": jax.random.normal(k1, (dim, hidden)) * 0.3,
          "b1": jnp.zeros((hidden,)),
          "w2": jax.random.normal(k2, (hidden, dim)) * 0.3,
          "b2": jnp.zeros((dim,))}
def field(x, t, p):
    h = jnp.tanh(x @ p["w1"] + p["b1"] + t)
    return h @ p["w2"] + p["b2"]
# heterogeneous magnitudes -> heterogeneous per-lane accepted grids
x0 = jax.random.normal(k3, (B, dim)) * jnp.linspace(
    0.5, 3.0, B)[:, None]
cfg = AdaptiveConfig(rtol=1e-8, atol=1e-10, max_steps=96)
"""

_SOLVE_SCRIPT = _PREAMBLE + r"""
for mesh, n_shards in [(make_lane_mesh((4,)), 4),
                       (make_lane_mesh((2, 2)), 4)]:
    for grad, stepping in [("symplectic", cfg), ("adjoint", cfg),
                           ("symplectic", 12), ("adjoint", 12)]:
        ref = solve(field, x0, params, gradient=grad, stepping=stepping,
                    batch_axis=0)
        sol = solve(field, x0, params, gradient=grad, stepping=stepping,
                    batch_axis=0, mesh=mesh)
        # integer stats + success: exact vs the full-width batch
        for k in ("n_steps", "n_fevals", "n_attempts"):
            np.testing.assert_array_equal(np.asarray(sol.stats[k]),
                                          np.asarray(ref.stats[k]), k)
        np.testing.assert_array_equal(np.asarray(sol.success),
                                      np.asarray(ref.success))
        # values: f64 rounding vs the full-width batch (batch-width-
        # dependent XLA codegen; test_batch.py precedent)
        np.testing.assert_allclose(np.asarray(sol.ys), np.asarray(ref.ys),
                                   rtol=0, atol=1e-11)
        # load-imbalance metric: per-shard totals partition the lane sum
        ss = np.asarray(sol.stats["shard_steps"])
        assert ss.shape == (n_shards,)
        assert ss.sum() == np.asarray(sol.stats["n_steps"]).sum()
        assert float(sol.stats["load_imbalance"]) >= 1.0
        # shard-local exactness: each shard's lane block is BITWISE the
        # single-device solve of that block
        per = B // n_shards
        for s in range(n_shards):
            blk = solve(field, x0[s * per:(s + 1) * per], params,
                        gradient=grad, stepping=stepping, batch_axis=0)
            assert np.array_equal(np.asarray(blk.ys),
                                  np.asarray(sol.ys[s * per:(s + 1) * per]))
        # gradients: <= 1e-12 vs unsharded, both strategies, both steppings
        def loss(p, x, mesh_):
            kw = {"mesh": mesh_} if mesh_ is not None else {}
            s = solve(field, x, p, gradient=grad, stepping=stepping,
                      batch_axis=0, **kw)
            return jnp.sum(jnp.sin(s.ys) ** 2)
        g_ref = jax.grad(loss, argnums=(0, 1))(params, x0, None)
        g_sh = jax.grad(loss, argnums=(0, 1))(params, x0, mesh)
        for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                        jax.tree_util.tree_leaves(g_sh)):
            assert float(jnp.max(jnp.abs(a - b))) <= 1e-12, (grad, stepping)
        print("ok", dict(mesh.shape), grad,
              stepping if isinstance(stepping, int) else "adaptive")
print("PASS")
"""

_GRIDS_SCRIPT = _PREAMBLE + r"""
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.core.rk import rk_solve_adaptive_batched
from repro.core.tableau import get_tableau
from repro.parallel import batched_solution_specs

tab = get_tableau("dopri5")
mesh = make_lane_mesh((4,))

def drv(x0_, params_):
    return rk_solve_adaptive_batched(field, tab, x0_, 0.0, 1.0, params_,
                                     cfg)

sh = jax.jit(shard_map(drv, mesh=mesh, in_specs=(P("data"), P()),
                       out_specs=batched_solution_specs(("data",)),
                       check_rep=False))(x0, params)
# every field of the sharded solution -- including accepted grids (ts, hs,
# xs) and the h carry -- is bitwise the jitted local solve of each lane
# block (shard_map's body compiles exactly the local program)
drv_j = jax.jit(drv)
for s in range(4):
    loc = drv_j(x0[2 * s:2 * s + 2], params)
    for name in loc._fields:
        for a, b in zip(jax.tree_util.tree_leaves(getattr(loc, name)),
                        jax.tree_util.tree_leaves(getattr(sh, name))):
            lane_ax = 1 if np.ndim(b) and b.shape[0] == cfg.max_steps \
                else 0
            blk = jax.lax.slice_in_dim(b, 2 * s, 2 * s + 2, axis=lane_ax)
            assert np.array_equal(np.asarray(a), np.asarray(blk)), \
                (s, name)
print("PASS")
"""

_SAVEAT_SCRIPT = _PREAMBLE + r"""
mesh = make_lane_mesh((4,))
ts = jnp.linspace(0.25, 1.0, 4)
for stepping in (cfg, 6):
    ref = solve(field, x0, params, saveat=SaveAt(ts=ts), stepping=stepping,
                batch_axis=0)
    sol = solve(field, x0, params, saveat=SaveAt(ts=ts), stepping=stepping,
                batch_axis=0, mesh=mesh)
    assert sol.ys.shape == (4, B, dim)
    np.testing.assert_allclose(np.asarray(sol.ys), np.asarray(ref.ys),
                               rtol=0, atol=1e-11)
    np.testing.assert_array_equal(np.asarray(sol.stats["n_steps"]),
                                  np.asarray(ref.stats["n_steps"]))
    np.testing.assert_allclose(np.asarray(sol.final_state),
                               np.asarray(ref.final_state), rtol=0,
                               atol=1e-11)
    def loss(p, mesh_):
        kw = {"mesh": mesh_} if mesh_ is not None else {}
        s = solve(field, x0, p, saveat=SaveAt(ts=ts), stepping=stepping,
                  batch_axis=0, **kw)
        return jnp.sum(jnp.sin(s.ys) ** 2)
    g_ref = jax.grad(loss)(params, None)
    g_sh = jax.grad(loss)(params, mesh)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_sh)):
        assert float(jnp.max(jnp.abs(a - b))) <= 1e-12

# rank-0 param leaves are lifted to (1,) at the shard_map boundary
# (lift_scalar_params) — grads must still come back scalar AND exact
def sfield(x, t, p):
    return p["gain"] * jnp.tanh(x @ p["w"])
sparams = {"gain": jnp.float64(0.7), "w": params["w1"][:dim, :dim]}
for strat, stepping in (("symplectic", cfg), ("adjoint", 8)):
    def sloss(p, mesh_):
        kw = {"mesh": mesh_} if mesh_ is not None else {}
        return jnp.sum(solve(sfield, x0, p, gradient=strat,
                             stepping=stepping, batch_axis=0, **kw).ys ** 2)
    g_ref = jax.grad(sloss)(sparams, None)
    g_sh = jax.jit(lambda p: jax.grad(sloss)(p, mesh))(sparams)
    assert jnp.ndim(g_sh["gain"]) == 0, g_sh["gain"].shape
    for k in sparams:
        assert float(jnp.max(jnp.abs(g_ref[k] - g_sh[k]))) <= 1e-12, \
            (strat, k)
print("PASS")
"""

_ENGINE_SCRIPT = _PREAMBLE + r"""
from repro.core.tableau import get_tableau
from repro.serve.engine import EngineConfig, Request, SolveEngine

tab = get_tableau("dopri5")
reqs = [Request(x0[i % B], 0.0, 0.5 + 0.05 * i, 1e-6 * (1 + i % 3), 1e-8)
        for i in range(10)]
mesh = make_lane_mesh((4,))
res = {}
for ecfg in (EngineConfig(buckets=(4, 8), mesh=mesh),
             EngineConfig(buckets=(4, 8))):
    eng = SolveEngine(field, tab, cfg, params, x0[0], ecfg)
    res[ecfg.mesh is not None] = (eng.run(list(reqs)), eng)
sharded, eng_s = res[True]
plain, _ = res[False]
assert set(sharded) == set(plain)
for rid in sharded:
    a, b = sharded[rid], plain[rid]
    assert (a.succeeded, a.n_accepted, a.n_fevals) \
        == (b.succeeded, b.n_accepted, b.n_fevals), rid
    assert float(jnp.max(jnp.abs(a.x_final - b.x_final))) <= 1e-12, rid
# the resident slot state actually lives lane-sharded on the mesh: lane
# fields on axis 0, step-major buffers on axis 1
t_spec = eng_s._state.t.sharding.spec
ts_spec = eng_s._state.ts.sharding.spec
assert "data" in str(t_spec), t_spec
assert len(ts_spec) >= 2 and ts_spec[0] is None \
    and "data" in str(ts_spec[1]), ts_spec
print("PASS")
"""

_SHARDER_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.launch.mesh import make_debug_mesh
from repro.parallel import make_sharder

mesh = make_debug_mesh(2, 2)          # ("data", "model")
shard = jax.jit(lambda x: make_sharder(mesh)(x, ("batch", "ffn")))
y = shard(jnp.ones((4, 8)))
assert "data" in str(y.sharding.spec) and "model" in str(y.sharding.spec)
# non-divisible dims are never constrained (trailing Nones may be
# normalized away by the sharding layer)
y6 = jax.jit(lambda x: make_sharder(mesh)(x, ("batch", "ffn")))(
    jnp.ones((4, 5)))
spec6 = y6.sharding.spec
assert len(spec6) < 2 or spec6[1] is None, spec6
print("PASS")
"""


@pytest.mark.parametrize("script", [_SOLVE_SCRIPT, _GRIDS_SCRIPT,
                                    _SAVEAT_SCRIPT, _ENGINE_SCRIPT,
                                    _SHARDER_SCRIPT],
                         ids=["solve", "grids", "saveat", "engine",
                              "sharder"])
def test_multidevice(run_sharded, script):
    assert "PASS" in run_sharded(script, devices=8)
