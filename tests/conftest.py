"""Suite-wide fixtures.

The whole suite runs in ONE process, and every eager custom-VJP call plus
every jitted helper leaves a live compiled executable in JAX's caches.  On
this CPU jaxlib that accumulation has a hard native ceiling: past a few
hundred tests' worth of executables, the next large eager compile (the
13-stage dopri8 symplectic backward scan is the biggest single unit)
segfaults inside XLA's LLVM JIT — deterministically at whatever test
happens to sit past the threshold, while the same test passes in any
smaller selection.  Dropping the caches at module boundaries keeps the
live-executable footprint bounded by the largest single module instead of
the whole suite; cross-module cache reuse is almost nil anyway (each
module compiles its own fields/methods), so the wall-time cost is noise.
"""
import os
import pathlib
import subprocess
import sys

import jax
import pytest

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


@pytest.fixture(autouse=True, scope="module")
def _bounded_compile_cache():
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def run_sharded():
    """Run a self-contained test script with N forced host devices.

    ``--xla_force_host_platform_device_count`` only takes effect BEFORE jax
    initializes its backend, and this suite's process initialized jax long
    ago (single-device) — so every multi-device test runs its script in a
    fresh subprocess with the flag set.  The script must be standalone
    (imports included) and signal failure by raising; stdout is returned
    for optional content assertions.
    """
    def run(source: str, devices: int = 8, timeout: int = 600) -> str:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices} "
            + env.get("XLA_FLAGS", ""))
        env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH",
                                                            "")
        proc = subprocess.run([sys.executable, "-c", source], env=env,
                              capture_output=True, text=True,
                              timeout=timeout)
        assert proc.returncode == 0, (
            f"sharded subprocess failed (rc={proc.returncode}):\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
        return proc.stdout
    return run
