"""Suite-wide fixtures.

The whole suite runs in ONE process, and every eager custom-VJP call plus
every jitted helper leaves a live compiled executable in JAX's caches.  On
this CPU jaxlib that accumulation has a hard native ceiling: past a few
hundred tests' worth of executables, the next large eager compile (the
13-stage dopri8 symplectic backward scan is the biggest single unit)
segfaults inside XLA's LLVM JIT — deterministically at whatever test
happens to sit past the threshold, while the same test passes in any
smaller selection.  Dropping the caches at module boundaries keeps the
live-executable footprint bounded by the largest single module instead of
the whole suite; cross-module cache reuse is almost nil anyway (each
module compiles its own fields/methods), so the wall-time cost is noise.
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _bounded_compile_cache():
    yield
    jax.clear_caches()
