"""Fault-injection harness: training survives process death (ISSUE 10).

Acceptance criteria pinned here:

  * SIGKILL the training driver mid-epoch at a (seeded-)random step —
    including MID async checkpoint save — resume with ``--resume``, and the
    resumed loss/grad-norm trajectory is BIT-identical to an uninterrupted
    golden run, for both the ``symplectic`` (the paper's exact-gradient
    method) and ``backprop`` modes.  Exactness is what makes this a spec:
    there is no tolerance to tune.
  * Elastic restart: a train-state pytree saved/resharded on a (4,) mesh
    restores onto a (2, 2) mesh (and round-trips back) value-identical,
    via ``runtime.elastic.reshard_state`` + ``Checkpointer`` shardings
    (the ``run_sharded`` subprocess fixture, tests/conftest.py).
  * ``runtime.failures.run_with_retries`` obeys its documented contract
    (property-tested): on_failure exactly once per failed attempt, linear
    backoff only before attempts that happen, non-retryable exceptions
    propagate unwrapped, success after k <= max_retries returns the value.
  * Train -> serve handoff: ``repro.serve`` boots from the params leaf of
    a TRAINING checkpoint (``SolveEngine.from_checkpoint`` in-process and
    ``launch.serve lm --ckpt-dir`` end-to-end).

The subprocess kill tests are compile-bound (each driver boot recompiles
the train step) and marked ``slow``; the CI train-smoke lane runs the same
kill/resume flow against the real CLI.
"""
import json
import os
import pathlib
import random
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # minimal containers: jax + pytest
    from hypothesis_compat import given, settings, st

from repro.runtime import Checkpointer, RetryConfig, run_with_retries

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


# ---------------------------------------------------------------------------
# run_with_retries: property tests of the documented contract
# ---------------------------------------------------------------------------

def _failing_fn(n_failures, exc=RuntimeError, value="ok"):
    calls = []

    def fn():
        calls.append(None)
        if len(calls) <= n_failures:
            raise exc(f"injected failure {len(calls)}")
        return value

    return fn, calls


@settings(max_examples=30, deadline=None)
@given(n_failures=st.integers(min_value=0, max_value=4),
       max_retries=st.integers(min_value=0, max_value=4))
def test_retry_contract(n_failures, max_retries):
    cfg = RetryConfig(max_retries=max_retries, backoff_s=0.5)
    fn, calls = _failing_fn(n_failures)
    failures, sleeps = [], []
    on_failure = lambda: failures.append(1)  # noqa: E731

    if n_failures <= max_retries:
        out = run_with_retries(fn, cfg, on_failure, sleeps.append)
        assert out == "ok"
        assert len(calls) == n_failures + 1
        # on_failure exactly once per failed attempt
        assert len(failures) == n_failures
        # linear backoff, paid only before attempts that happen
        assert sleeps == [0.5 * k for k in range(1, n_failures + 1)]
    else:
        with pytest.raises(RuntimeError, match="injected failure"):
            run_with_retries(fn, cfg, on_failure, sleeps.append)
        assert len(calls) == max_retries + 1
        # ...including the final attempt whose exception propagates
        assert len(failures) == max_retries + 1
        # never a sleep after the last attempt
        assert sleeps == [0.5 * k for k in range(1, max_retries + 1)]


@settings(max_examples=10, deadline=None)
@given(exc=st.sampled_from([ValueError, KeyError, ArithmeticError]))
def test_retry_non_retryable_propagates_unwrapped(exc):
    cfg = RetryConfig(max_retries=3, retryable=(RuntimeError,))
    fn, calls = _failing_fn(5, exc=exc)
    failures, sleeps = [], []
    with pytest.raises(exc):
        run_with_retries(fn, cfg, lambda: failures.append(1),
                         sleeps.append)
    # immediate: one call, no on_failure, no backoff
    assert len(calls) == 1 and failures == [] and sleeps == []


def test_retry_on_failure_can_mutate_state():
    """The advertised use: on_failure restores state before the retry."""
    state = {"good": False}
    cfg = RetryConfig(max_retries=2, backoff_s=0.0)

    def fn():
        if not state["good"]:
            raise RuntimeError("bad state")
        return 42

    def on_failure():
        state["good"] = True

    assert run_with_retries(fn, cfg, on_failure, lambda s: None) == 42


# ---------------------------------------------------------------------------
# subprocess kill/resume harness
# ---------------------------------------------------------------------------

TOTAL_STEPS = 8     # 2 epochs x 4 steps; every run MUST use the same total
#                     (the LR schedule depends on it — a different total is
#                     a different trajectory, not a resume bug)
TRAIN_ARGS = ["--arch", "qwen3-0.6b", "--smoke", "--epochs", "2",
              "--steps-per-epoch", "4", "--global-batch", "2",
              "--seq-len", "16", "--ckpt-every", "2"]


def _train_cmd(grad_mode, ckpt_dir, metrics, extra=()):
    cmd = [sys.executable, "-m", "repro.launch.train", *TRAIN_ARGS,
           "--grad-mode", grad_mode, "--metrics-out", str(metrics)]
    if ckpt_dir is not None:
        cmd += ["--ckpt-dir", str(ckpt_dir)]
    return cmd + list(extra)


def _env(**extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    env.update(extra)
    return env


def _run(cmd, env, timeout=600):
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=timeout)
    assert proc.returncode == 0, (
        f"driver failed (rc={proc.returncode}):\n--- stdout ---\n"
        f"{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    return proc


def _load_metrics(path) -> dict:
    rows = {}
    with open(path) as f:
        for line in f:
            if line.strip():
                rec = json.loads(line)
                rows[int(rec["step"])] = rec
    return rows


def _assert_bit_identical(golden, other, min_overlap=2):
    """json round-trips python floats exactly, so == is exact-bits."""
    common = sorted(set(golden) & set(other))
    assert len(common) >= min_overlap, (
        f"only {len(common)} overlapping steps (need >= {min_overlap})")
    for step in common:
        for key in ("loss", "grad_norm", "lr"):
            assert golden[step][key] == other[step][key], (
                f"step {step} {key}: golden={golden[step][key]!r} "
                f"other={other[step][key]!r}")


def _kill_when(proc, predicate, timeout=180):
    """SIGKILL ``proc`` once ``predicate()`` holds; False if it finished
    first (the fault never landed)."""
    t0 = time.time()
    try:
        while time.time() - t0 < timeout:
            if predicate():
                proc.kill()
                proc.wait()
                return True
            if proc.poll() is not None:
                return False
            time.sleep(0.02)
        raise AssertionError("kill condition never became true")
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()


@pytest.fixture(scope="session")
def golden_metrics(tmp_path_factory):
    """Uninterrupted reference runs, computed once per grad mode."""
    cache = {}

    def get(grad_mode):
        if grad_mode not in cache:
            d = tmp_path_factory.mktemp(f"golden_{grad_mode}")
            path = d / "golden.jsonl"
            _run(_train_cmd(grad_mode, None, path), _env())
            rows = _load_metrics(path)
            assert sorted(rows) == list(range(TOTAL_STEPS))
            cache[grad_mode] = rows
        return cache[grad_mode]

    return get


@pytest.mark.slow
@pytest.mark.parametrize("grad_mode", ["symplectic", "backprop"])
def test_sigkill_mid_epoch_resume_bit_identical(tmp_path, golden_metrics,
                                                grad_mode):
    golden = golden_metrics(grad_mode)
    # randomized-but-reproducible kill step, always past the first
    # checkpoint (ckpt-every 2) and before the end
    kill_after = random.Random(f"kill-{grad_mode}").randint(3, 6)
    ckpt = tmp_path / "ckpt"
    victim = tmp_path / "victim.jsonl"
    victim.touch()
    proc = subprocess.Popen(
        _train_cmd(grad_mode, ckpt, victim, ["--step-delay-s", "0.25"]),
        env=_env(), stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    killed = _kill_when(
        proc, lambda: len(victim.read_text().splitlines()) >= kill_after)
    assert killed, "driver finished before the fault landed (pacing broken)"
    done = _load_metrics(victim)
    assert len(done) < TOTAL_STEPS, "kill landed after the last step"

    resumed = tmp_path / "resumed.jsonl"
    out = _run(_train_cmd(grad_mode, ckpt, resumed, ["--resume"]), _env())
    assert "resumed from step" in out.stdout

    rows = _load_metrics(resumed)
    # the resumed run replays from the newest checkpoint to the end...
    assert max(rows) == TOTAL_STEPS - 1
    # ...and every step — the victim's prefix AND the resumed suffix — is
    # bit-identical to the uninterrupted run
    _assert_bit_identical(golden, rows, min_overlap=2)
    _assert_bit_identical(golden, done, min_overlap=1)
    assert set(done) | set(rows) == set(range(TOTAL_STEPS))


@pytest.mark.slow
def test_sigkill_mid_async_save_resume(tmp_path, golden_metrics):
    """Kill DURING an async checkpoint write (between the array write and
    the manifest publish — REPRO_CKPT_WRITE_DELAY_S holds that window
    open).  The half-written ``.tmp_step_*`` must be invisible to restore,
    swept on the next boot, and the resumed trajectory bit-identical."""
    golden = golden_metrics("symplectic")
    ckpt = tmp_path / "ckpt"
    victim = tmp_path / "victim.jsonl"
    victim.touch()
    proc = subprocess.Popen(
        _train_cmd("symplectic", ckpt, victim, ["--step-delay-s", "0.1"]),
        env=_env(REPRO_CKPT_WRITE_DELAY_S="1.5"),
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)

    def mid_save_with_fallback():
        # wait for: one PUBLISHED checkpoint (so resume has something) AND
        # a live tmp dir (a save in its injected-delay window)
        if not ckpt.exists():
            return False
        names = os.listdir(ckpt)
        published = any(
            n.startswith("step_")
            and (ckpt / n / "MANIFEST.json").exists() for n in names)
        in_flight = any(n.startswith(".tmp_step_") for n in names)
        return published and in_flight

    killed = _kill_when(proc, mid_save_with_fallback)
    assert killed, "driver finished before a mid-save kill window opened"
    stale = [n for n in os.listdir(ckpt) if n.startswith(".tmp_step_")]
    assert stale, "kill did not land mid async save"

    resumed = tmp_path / "resumed.jsonl"
    out = _run(_train_cmd("symplectic", ckpt, resumed, ["--resume"]),
               _env())
    assert "resumed from step" in out.stdout
    # the stale tmp dir was swept on boot (Checkpointer init)
    assert not any(n.startswith(".tmp_step_") for n in os.listdir(ckpt))
    _assert_bit_identical(golden, _load_metrics(resumed), min_overlap=2)


# ---------------------------------------------------------------------------
# elastic restart: (4,) -> (2, 2) on real (forced-host) devices
# ---------------------------------------------------------------------------

_ELASTIC_SCRIPT = r"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_arch
from repro.launch.mesh import make_debug_mesh, make_lane_mesh
from repro.parallel import state_specs
from repro.runtime import Checkpointer, mesh_shardings, reshard_state
from repro.train import TrainConfig, init_train_state

arch = get_smoke_arch("qwen3-0.6b")
state = init_train_state(jax.random.PRNGKey(0), arch, TrainConfig())
ref = jax.tree_util.tree_leaves(jax.tree_util.tree_map(np.asarray, state))

mesh1 = make_lane_mesh((4,))        # ("data",)       — 4-way DP
mesh2 = make_debug_mesh(2, 2)       # ("data","model") — 2x2 after restart
specs1 = state_specs(state, mesh1)
specs2 = state_specs(state, mesh2)

# live reshard (pod loss / regrowth): (4,) -> (2, 2) -> (4,)
s1 = reshard_state(state, mesh1, specs1)
s2 = reshard_state(s1, mesh2, specs2)
s3 = reshard_state(s2, mesh1, specs1)
for name, s in (("s2", s2), ("s3", s3)):
    for a, b in zip(ref, jax.tree_util.tree_leaves(s)):
        np.testing.assert_array_equal(a, np.asarray(b), err_msg=name)
# the (2, 2) mesh actually shards something (embed etc. over "model")
assert any(not l.sharding.is_fully_replicated
           for l in jax.tree_util.tree_leaves(s2)), "nothing sharded"

# checkpoint written under the (4,) topology restores under (2, 2)
d = tempfile.mkdtemp()
Checkpointer(d).save(5, s1)
sh2 = mesh_shardings(mesh2, specs2)
restored, step = Checkpointer(d).restore(state, shardings=sh2)
assert step == 5
for a, b in zip(ref, jax.tree_util.tree_leaves(restored)):
    np.testing.assert_array_equal(a, np.asarray(b))
for l, sh in zip(jax.tree_util.tree_leaves(restored),
                 jax.tree_util.tree_leaves(
                     sh2, is_leaf=lambda x: isinstance(
                         x, jax.sharding.Sharding))):
    assert l.sharding == sh, (l.sharding, sh)
print("PASS")
"""


def test_elastic_restart_mesh_shape_change(run_sharded):
    out = run_sharded(_ELASTIC_SCRIPT, devices=4)
    assert "PASS" in out


# ---------------------------------------------------------------------------
# train -> serve handoff
# ---------------------------------------------------------------------------

def test_solve_engine_from_training_checkpoint(tmp_path):
    """The ODE serve engine boots from the params leaf of a training
    checkpoint and produces results identical to an engine built from the
    live params."""
    from repro.core import AdaptiveConfig
    from repro.core.tableau import get_tableau
    from repro.serve import EngineConfig, Request, SolveEngine
    from repro.train.state import TrainState, init_solver_stats

    k = jax.random.split(jax.random.PRNGKey(3), 2)
    params = {"w": jax.random.normal(k[0], (4, 4)) * 0.3,
              "b": jax.random.normal(k[1], (4,)) * 0.1}

    def field(x, t, p):
        return jnp.tanh(x @ p["w"] + p["b"])

    trained = TrainState(params=params, opt={"step": jnp.int32(11)},
                         rng=jax.random.PRNGKey(9),
                         data_step=jnp.int32(11),
                         solver_stats=init_solver_stats())
    Checkpointer(str(tmp_path)).save(11, trained)

    like = jax.tree_util.tree_map(jnp.zeros_like, trained)
    cfg = AdaptiveConfig(rtol=1e-4, atol=1e-6, max_steps=64,
                         initial_step=0.05)
    eng = SolveEngine.from_checkpoint(
        field, get_tableau("bosh3"), cfg, str(tmp_path), like,
        x0_template=jnp.zeros((4,)), engine_cfg=EngineConfig(buckets=(2,)))
    assert eng.restored_step == 11
    ref = SolveEngine(field, get_tableau("bosh3"), cfg, params,
                      jnp.zeros((4,)), EngineConfig(buckets=(2,)))

    x0 = jax.random.normal(jax.random.PRNGKey(7), (4,))
    req = Request(x0=x0, t0=0.0, t1=0.5, rtol=1e-4, atol=1e-6)
    (r_ck,) = eng.run([req]).values()
    (r_ref,) = ref.run([req]).values()
    assert r_ck.succeeded and r_ref.succeeded
    np.testing.assert_array_equal(np.asarray(r_ck.x_final),
                                  np.asarray(r_ref.x_final))
    assert r_ck.n_fevals == r_ref.n_fevals


def test_params_from_checkpoint_rejects_wrong_contract(tmp_path):
    """A mismatched restore template is a clear shape-contract error."""
    from repro.serve import params_from_checkpoint
    from repro.train.state import TrainState, init_solver_stats

    state = TrainState(params={"w": jnp.ones((2, 2))}, opt={},
                       rng=jax.random.PRNGKey(0), data_step=jnp.int32(0),
                       solver_stats=init_solver_stats())
    Checkpointer(str(tmp_path)).save(1, state)
    wrong = state.replace(
        params={"w": jnp.ones((2, 2)), "extra": jnp.ones(3)})
    with pytest.raises(ValueError, match="shape-contract mismatch"):
        params_from_checkpoint(str(tmp_path), wrong)


@pytest.mark.slow
def test_lm_serve_boots_from_training_checkpoint(tmp_path):
    """End-to-end CLI handoff: train a few steps with checkpoints, then
    ``launch.serve lm --ckpt-dir`` decodes with the trained params."""
    ckpt = tmp_path / "ckpt"
    _run([sys.executable, "-m", "repro.launch.train", "--arch",
          "qwen3-0.6b", "--smoke", "--steps", "2", "--global-batch", "2",
          "--seq-len", "16", "--grad-mode", "symplectic",
          "--ckpt-dir", str(ckpt), "--ckpt-every", "2"], _env())
    out = _run([sys.executable, "-m", "repro.launch.serve", "lm",
                "--arch", "qwen3-0.6b", "--smoke", "--grad-mode",
                "symplectic", "--ckpt-dir", str(ckpt), "--batch", "2",
                "--prompt-len", "8", "--gen-len", "4"], _env())
    assert f"restored params from {ckpt} step 2" in out.stdout
    assert "sample generation" in out.stdout
