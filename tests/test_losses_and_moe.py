"""Unit + property tests: chunked CE loss, MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal container: run fixed examples instead
    from hypothesis_compat import given, settings, st

from repro.nn.moe import MoEConfig, init_moe, moe_ffn
from repro.train.losses import IGNORE, lm_loss, lm_loss_chunked


def test_chunked_loss_matches_full():
    key = jax.random.PRNGKey(0)
    B, S, d, V = 2, 48, 16, 97
    hidden = jax.random.normal(key, (B, S, d))
    head = jax.random.normal(jax.random.PRNGKey(1), (d, V))
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
    labels = labels.at[0, :5].set(IGNORE)
    full = lm_loss((hidden @ head)[None][0].astype(jnp.float32), labels)
    for chunk in (8, 16, 48, 7):   # 7: padding path
        got = lm_loss_chunked(hidden, head, labels, chunk)
        np.testing.assert_allclose(float(got), float(full), rtol=1e-6)


def test_chunked_loss_grad_matches_full():
    key = jax.random.PRNGKey(3)
    B, S, d, V = 2, 32, 8, 33
    hidden = jax.random.normal(key, (B, S, d))
    head = jax.random.normal(jax.random.PRNGKey(4), (d, V))
    labels = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, V)

    g1 = jax.grad(lambda h, w: lm_loss((h @ w).astype(jnp.float32),
                                       labels), argnums=(0, 1))(hidden, head)
    g2 = jax.grad(lambda h, w: lm_loss_chunked(h, w, labels, 8),
                  argnums=(0, 1))(hidden, head)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def _moe_setup(S=32, d=16, E=4, k=2, cf=4.0):
    cfg = MoEConfig(d_model=d, d_ff=32, n_experts=E, top_k=k,
                    capacity_factor=cf)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, d))
    return cfg, p, x


def test_moe_matches_dense_loop_reference():
    """Sort-based dispatch == brute-force per-token expert evaluation when
    capacity is unbounded."""
    cfg, p, x = _moe_setup(cf=10.0)   # no drops
    y, aux = moe_ffn(p, x, cfg)

    # reference: evaluate every expert densely per token
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gw, gi = jax.lax.top_k(probs, cfg.top_k)
    gw = gw / jnp.maximum(gw.sum(-1, keepdims=True), 1e-9)
    ref = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(x @ p["wg"][e]) * (x @ p["wu"][e])
        ye = h @ p["wd"][e]
        w = jnp.sum(jnp.where(gi == e, gw, 0.0), -1)
        ref = ref + ye * w[..., None].astype(ye.dtype)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_moe_capacity_drops_are_bounded():
    """With capacity factor 1.0 some tokens may drop but output stays
    finite and aux loss is positive."""
    cfg, p, x = _moe_setup(cf=1.0)
    y, aux = moe_ffn(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) > 0


@pytest.mark.slow   # each drawn shape recompiles the dispatch
@settings(max_examples=10, deadline=None)
@given(S=st.sampled_from([8, 16, 64]), E=st.sampled_from([2, 4, 8]),
       k=st.integers(1, 2))
def test_moe_dispatch_property(S, E, k):
    """Each expert processes at most C tokens; gates of processed slots
    sum to <= 1 per token (property over random shapes)."""
    cfg = MoEConfig(d_model=8, d_ff=16, n_experts=E, top_k=k,
                    capacity_factor=1.25)
    p = init_moe(jax.random.PRNGKey(E * 10 + k), cfg)
    x = jax.random.normal(jax.random.PRNGKey(S), (1, S, 8))
    y, aux = moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
