"""SaveAt trajectory observation + adaptive-controller bugfix tests.

Covers the acceptance criteria of the SaveAt subsystem:
  * odeint(..., ts=...) observes the solution at user times for ALL five
    gradient modes, on fixed and adaptive grids;
  * grad_mode="symplectic" matches jax.grad through the (segmented)
    discrete solver to rounding error for losses over >= 3 interior
    observation times, for dopri5 AND bosh3;
  * reverse-time (t1 < t0) and zero-length (t0 == t1) solves across all
    modes, including gradient exactness for the symplectic mode;
  * the adaptive controller fixes: unclamped-h carry across landing steps,
    dtype-aware termination threshold, and the ``succeeded`` flag with
    configurable on-failure policy.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import (AdaptiveConfig, GRAD_MODES, get_tableau, odeint,
                        odeint_with_stats)
from repro.core.rk import (_time_resolution, rk_solve_adaptive,
                           rk_solve_adaptive_saveat, rk_step)

# Deliberately exercises the deprecated odeint shims (shim regression suite).
pytestmark = pytest.mark.filterwarnings(
    "ignore:odeint-style entry point:DeprecationWarning")

ALL_MODES = list(GRAD_MODES)
ADAPTIVE_MODES = ["symplectic", "backprop", "adjoint"]


def mlp_field(x, t, params):
    h = jnp.tanh(params["w1"] @ x + params["b1"] + t)
    return params["w2"] @ h + params["b2"]


def make_params(key, dim=4, hidden=6):
    ks = jax.random.split(key, 4)
    return {
        "w1": jax.random.normal(ks[0], (hidden, dim)) * 0.5,
        "b1": jax.random.normal(ks[1], (hidden,)) * 0.1,
        "w2": jax.random.normal(ks[2], (dim, hidden)) * 0.5,
        "b2": jax.random.normal(ks[3], (dim,)) * 0.1,
    }


def linear(x, t, p):
    return p["lam"] * x


LIN_P = {"lam": jnp.asarray(-0.7)}
TS3 = jnp.array([0.25, 0.5, 0.875])


# --- observation correctness -------------------------------------------------

@pytest.mark.parametrize("mode", ALL_MODES)
def test_saveat_fixed_matches_chained_segments(mode):
    """ts observation == chaining per-segment solves (the same discrete map),
    and tracks the closed-form solution."""
    x0 = jnp.asarray([1.0, 2.0, -0.5])
    ys = odeint(linear, x0, LIN_P, ts=TS3, method="dopri5", grad_mode=mode,
                n_steps=6)
    assert ys.shape == (3,) + x0.shape
    x, t_prev = x0, 0.0
    for i in range(3):
        x = odeint(linear, x, LIN_P, t0=t_prev, t1=TS3[i], method="dopri5",
                   grad_mode=mode, n_steps=6)
        np.testing.assert_allclose(np.asarray(ys[i]), np.asarray(x),
                                   rtol=1e-12, atol=1e-14)
        t_prev = TS3[i]
    exact = x0 * jnp.exp(LIN_P["lam"] * TS3[:, None])
    np.testing.assert_allclose(np.asarray(ys), np.asarray(exact), rtol=1e-8)


@pytest.mark.parametrize("mode", ADAPTIVE_MODES)
def test_saveat_adaptive_observes(mode):
    x0 = jnp.asarray([1.0, 2.0, -0.5])
    cfg = AdaptiveConfig(rtol=1e-8, atol=1e-10, max_steps=64,
                         initial_step=0.05)
    ys = odeint(linear, x0, LIN_P, ts=TS3, method="dopri5", grad_mode=mode,
                adaptive=cfg)
    exact = x0 * jnp.exp(LIN_P["lam"] * TS3[:, None])
    np.testing.assert_allclose(np.asarray(ys), np.asarray(exact), rtol=1e-6)


def test_saveat_rejects_t1():
    with pytest.raises(ValueError, match="EITHER t1 or ts"):
        odeint(linear, jnp.ones(2), LIN_P, t1=1.0, ts=TS3)


def test_saveat_dense_requires_adaptive_backprop():
    with pytest.raises(ValueError, match="dense"):
        odeint(linear, jnp.ones(2), LIN_P, ts=TS3, ts_mode="dense",
               grad_mode="symplectic",
               adaptive=AdaptiveConfig())
    with pytest.raises(ValueError, match="dense"):
        odeint(linear, jnp.ones(2), LIN_P, ts=TS3, ts_mode="dense",
               grad_mode="backprop", n_steps=4)


# --- gradient exactness (the acceptance criterion) ---------------------------

@pytest.mark.parametrize("method", ["dopri5", "bosh3"])
def test_saveat_symplectic_gradient_exact_fixed(method):
    """Loss over 3 interior observation times: symplectic == jax.grad
    through the segmented solver, to rounding."""
    params = make_params(jax.random.PRNGKey(0))
    x0 = jax.random.normal(jax.random.PRNGKey(1), (4,))
    w = jnp.arange(1.0, 4.0)

    def loss(x0, params, mode):
        ys = odeint(mlp_field, x0, params, ts=TS3, method=method,
                    grad_mode=mode, n_steps=5)
        return jnp.sum(w[:, None] * jnp.sin(ys) ** 2)

    g_ref = jax.grad(loss, argnums=(0, 1))(x0, params, "backprop")
    g_sym = jax.grad(loss, argnums=(0, 1))(x0, params, "symplectic")
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_sym)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-10, atol=1e-12)


@pytest.mark.slow   # unrolled replay reference over every accepted step
@pytest.mark.parametrize("method", ["dopri5", "bosh3"])
def test_saveat_symplectic_gradient_exact_adaptive(method):
    """Adaptive SaveAt: the symplectic backward pass reproduces the exact
    gradient of the realized segmented discrete map.  Reference: replay the
    recorded accepted steps of every segment as a differentiable unrolled
    solve with the observation loss applied at each segment boundary."""
    params = make_params(jax.random.PRNGKey(2))
    x0 = jax.random.normal(jax.random.PRNGKey(3), (4,))
    tab = get_tableau(method)
    cfg = AdaptiveConfig(rtol=1e-6, atol=1e-8, max_steps=64,
                         initial_step=0.1)
    w = jnp.arange(1.0, 4.0)

    obs, sols = rk_solve_adaptive_saveat(mlp_field, tab, x0, 0.0, TS3,
                                         params, cfg)
    segs = []
    for s in sols:
        assert bool(s.succeeded)
        n = int(s.n_accepted)
        assert 0 < n < cfg.max_steps
        segs.append((np.asarray(s.ts)[:n], np.asarray(s.hs)[:n]))

    def loss_replay(x0, params):
        x, tot = x0, 0.0
        for i, (tseq, hseq) in enumerate(segs):
            for t, h in zip(tseq, hseq):
                x, _ = rk_step(mlp_field, tab, x, jnp.asarray(t),
                               jnp.asarray(h), params)
            tot = tot + jnp.sum(w[i] * jnp.sin(x) ** 2)
        return tot

    def loss_sym(x0, params):
        ys = odeint(mlp_field, x0, params, ts=TS3, method=method,
                    grad_mode="symplectic", adaptive=cfg)
        return jnp.sum(w[:, None] * jnp.sin(ys) ** 2)

    # the symplectic SaveAt primal must equal the replay states
    ys = odeint(mlp_field, x0, params, ts=TS3, method=method,
                grad_mode="symplectic", adaptive=cfg)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(obs), rtol=1e-12)

    g_ref = jax.grad(loss_replay, argnums=(0, 1))(x0, params)
    g_sym = jax.grad(loss_sym, argnums=(0, 1))(x0, params)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_sym)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-9, atol=1e-11)


# --- dense output ------------------------------------------------------------

def test_dense_output_accuracy():
    """Hermite dense output tracks the true solution without the observation
    times entering the step sequence."""
    x0 = jnp.asarray([1.0, -2.0])
    cfg = AdaptiveConfig(rtol=1e-8, atol=1e-10, max_steps=256,
                         initial_step=0.02)
    taus = jnp.array([0.1, 0.37, 0.52, 0.81, 1.0])
    ys, stats = odeint_with_stats(linear, x0, LIN_P, ts=taus,
                                  method="dopri5", adaptive=cfg)
    assert bool(stats["succeeded"])
    exact = x0 * jnp.exp(LIN_P["lam"] * taus[:, None])
    np.testing.assert_allclose(np.asarray(ys), np.asarray(exact), rtol=1e-6)
    # same step sequence as the unobserved solve: the controller never saw ts
    _, stats0 = odeint_with_stats(linear, x0, LIN_P, t1=1.0,
                                  method="dopri5", adaptive=cfg)
    assert int(stats["n_steps"]) == int(stats0["n_steps"])
    assert int(stats["n_fevals"]) == int(stats0["n_fevals"]) + 2 * 5

    # differentiable dense path (grad_mode="backprop", ts_mode="dense")
    ys2 = odeint(linear, x0, LIN_P, ts=taus, method="dopri5",
                 grad_mode="backprop", adaptive=cfg, ts_mode="dense")
    np.testing.assert_allclose(np.asarray(ys2), np.asarray(ys), rtol=1e-12)


def test_dense_output_endpoints_exact():
    """At accepted-step endpoints the interpolant is exact (theta in {0,1})."""
    x0 = jnp.asarray([0.3, 1.7])
    cfg = AdaptiveConfig(rtol=1e-6, atol=1e-8, max_steps=64,
                         initial_step=0.1)
    tab = get_tableau("dopri5")
    sol = rk_solve_adaptive(linear, tab, x0, 0.0, 1.0, LIN_P, cfg)
    n = int(sol.n_accepted)
    from repro.core import hermite_observe
    taus = jnp.asarray(np.asarray(sol.ts)[1:n])   # interior step starts
    ys = hermite_observe(linear, tab, sol, LIN_P, taus)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(sol.xs)[1:n],
                               rtol=1e-12, atol=1e-14)


# --- reverse time and zero-length intervals ----------------------------------

@pytest.mark.parametrize("mode", ALL_MODES)
def test_reverse_time_fixed(mode):
    x0 = jnp.asarray([1.0, 0.5])
    y = odeint(linear, x0, LIN_P, t0=1.0, t1=0.0, method="dopri5",
               grad_mode=mode, n_steps=8)
    exact = x0 * jnp.exp(LIN_P["lam"] * (0.0 - 1.0))
    np.testing.assert_allclose(np.asarray(y), np.asarray(exact), rtol=1e-8)


@pytest.mark.parametrize("method", ["dopri5", "bosh3"])
def test_reverse_time_symplectic_gradient_exact(method):
    params = make_params(jax.random.PRNGKey(4))
    x0 = jax.random.normal(jax.random.PRNGKey(5), (4,))
    ts_rev = jnp.array([0.6, 0.3, 0.0])   # monotone in integration direction

    def loss(x0, params, mode):
        ys = odeint(mlp_field, x0, params, t0=1.0, ts=ts_rev, method=method,
                    grad_mode=mode, n_steps=5)
        return jnp.sum(jnp.cos(ys))

    g_ref = jax.grad(loss, argnums=(0, 1))(x0, params, "backprop")
    g_sym = jax.grad(loss, argnums=(0, 1))(x0, params, "symplectic")
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_sym)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-10, atol=1e-12)


@pytest.mark.parametrize("mode", ADAPTIVE_MODES)
def test_reverse_time_adaptive(mode):
    x0 = jnp.asarray([1.0, 0.5])
    cfg = AdaptiveConfig(rtol=1e-8, atol=1e-10, max_steps=128,
                         initial_step=0.05)
    y = odeint(linear, x0, LIN_P, t0=1.0, t1=0.0, method="dopri5",
               grad_mode=mode, adaptive=cfg)
    exact = x0 * jnp.exp(LIN_P["lam"] * (0.0 - 1.0))
    np.testing.assert_allclose(np.asarray(y), np.asarray(exact), rtol=1e-6)


@pytest.mark.parametrize("mode", ALL_MODES)
def test_zero_length_interval_fixed(mode):
    params = make_params(jax.random.PRNGKey(6))
    x0 = jax.random.normal(jax.random.PRNGKey(7), (4,))
    y = odeint(mlp_field, x0, params, t0=0.5, t1=0.5, method="dopri5",
               grad_mode=mode, n_steps=4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x0),
                               rtol=0, atol=1e-15)
    # d(sum y)/d x0 == ones exactly: the zero-length map is the identity
    g = jax.grad(lambda x: jnp.sum(odeint(
        mlp_field, x, params, t0=0.5, t1=0.5, method="dopri5",
        grad_mode=mode, n_steps=4)))(x0)
    np.testing.assert_allclose(np.asarray(g), np.ones(4),
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("mode", ADAPTIVE_MODES)
def test_zero_length_interval_adaptive(mode):
    params = make_params(jax.random.PRNGKey(8))
    x0 = jax.random.normal(jax.random.PRNGKey(9), (4,))
    cfg = AdaptiveConfig(max_steps=16, initial_step=0.1)
    y = odeint(mlp_field, x0, params, t0=0.5, t1=0.5, method="dopri5",
               grad_mode=mode, adaptive=cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x0),
                               rtol=0, atol=1e-15)
    if mode == "backprop":
        return  # reverse-mode through lax.while_loop is unsupported
    g = jax.grad(lambda x: jnp.sum(odeint(
        mlp_field, x, params, t0=0.5, t1=0.5, method="dopri5",
        grad_mode=mode, adaptive=cfg)))(x0)
    np.testing.assert_allclose(np.asarray(g), np.ones(4),
                               rtol=1e-12, atol=1e-12)


def test_saveat_repeated_observation_time():
    """A duplicate observation time is a zero-length segment: both rows
    observe the same state and gradients stay exact."""
    params = make_params(jax.random.PRNGKey(10))
    x0 = jax.random.normal(jax.random.PRNGKey(11), (4,))
    ts = jnp.array([0.5, 0.5, 1.0])

    def loss(x0, params, mode):
        ys = odeint(mlp_field, x0, params, ts=ts, method="bosh3",
                    grad_mode=mode, n_steps=4)
        return jnp.sum(jnp.sin(ys) ** 2)

    ys = odeint(mlp_field, x0, params, ts=ts, method="bosh3",
                grad_mode="symplectic", n_steps=4)
    np.testing.assert_allclose(np.asarray(ys[0]), np.asarray(ys[1]),
                               rtol=0, atol=1e-15)
    g_ref = jax.grad(loss, argnums=(0, 1))(x0, params, "backprop")
    g_sym = jax.grad(loss, argnums=(0, 1))(x0, params, "symplectic")
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_sym)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-10, atol=1e-12)


# --- adaptive-controller bugfixes --------------------------------------------

def test_controller_h_carry_survives_landing_clamp():
    """A tiny clamped landing step must not collapse the carried step: the
    controller bases continuations on the UNCLAMPED h."""
    tab = get_tableau("dopri5")
    cfg = AdaptiveConfig(rtol=1e-3, atol=1e-6, max_steps=64,
                         initial_step=0.25)
    # the final step is clamped to ~1e-9
    sol = rk_solve_adaptive(linear, tab, jnp.ones(2), 0.0, 0.5 + 1e-9,
                            LIN_P, cfg)
    assert bool(sol.succeeded)
    assert abs(float(sol.h_final)) > 0.1, float(sol.h_final)


def test_controller_h_threads_across_segments():
    """Segmented SaveAt solves seed each segment from the previous
    h_final: an observation time never resets the controller to
    initial_step."""
    tab = get_tableau("dopri5")
    cfg = AdaptiveConfig(rtol=1e-6, atol=1e-8, max_steps=64,
                         initial_step=1e-4)
    ts = jnp.array([0.5, 1.0])
    _, sols = rk_solve_adaptive_saveat(linear, tab, jnp.ones(2), 0.0, ts,
                                       LIN_P, cfg)
    assert all(bool(s.succeeded) for s in sols)
    # without threading, segment 2 restarts at initial_step=1e-4 and needs
    # many doublings; with threading it continues at the grown step.
    assert int(sols[1].n_accepted) <= int(sols[0].n_accepted) // 2, \
        [int(s.n_accepted) for s in sols]


def test_controller_rejected_landing_clamp_keeps_h():
    """A REJECTED t1-clamped landing step must retry from the unclamped h,
    mirroring the accepted-step fix: shrinking from h_eff (the t1 gap)
    collapses the carried step to gap scale."""
    tab = get_tableau("dopri5")
    stiff = {"lam": jnp.asarray(-1e4)}
    # one attempt: the trial is clamped from 1.0 to the 1e-3 gap and
    # rejected (lam * h_eff = 10 >> 1), so h_final IS the retry step.
    cfg = AdaptiveConfig(rtol=1e-6, atol=1e-9, max_steps=8, max_attempts=1,
                         initial_step=1.0)
    sol = rk_solve_adaptive(linear, tab, jnp.ones(2), 0.0, 1e-3, stiff, cfg)
    assert not bool(sol.succeeded)
    assert int(sol.n_accepted) == 0            # the landing trial rejected
    # retry from the unclamped h: 1.0 * min_factor = 0.2.  The old update
    # retried from the clamped gap: 1e-3 * 0.2 = 2e-4.
    assert abs(float(sol.h_final)) > 0.1, float(sol.h_final)


def test_stiff_landing_interval_converges():
    """End-to-end stiff landing segment: geometric decay from the unclamped
    h still reaches the stable step and the solve lands accurately within
    the attempt budget (regression for the retry-base change)."""
    tab = get_tableau("dopri5")
    stiff = {"lam": jnp.asarray(-1e4)}
    cfg = AdaptiveConfig(rtol=1e-6, atol=1e-9, max_steps=256,
                         initial_step=1.0)
    sol = rk_solve_adaptive(linear, tab, jnp.ones(2), 0.0, 1e-3, stiff, cfg)
    assert bool(sol.succeeded)
    assert int(sol.n_attempts) < 120, int(sol.n_attempts)
    np.testing.assert_allclose(np.asarray(sol.x_final),
                               np.exp(-10.0) * np.ones(2), rtol=1e-4)


def test_direct_driver_time_cotangent_dtypes():
    """Drivers called directly (bypassing odeint's time coercion) must
    return time cotangents in the dtype the caller passed — here float32
    times under x64."""
    from repro.core import (odeint_adjoint, odeint_symplectic,
                            odeint_symplectic_adaptive,
                            odeint_symplectic_saveat,
                            odeint_symplectic_saveat_adaptive)
    tab = get_tableau("dopri5")
    x0 = jnp.ones(3)
    t0, t1 = jnp.float32(0.0), jnp.float32(1.0)
    ts32 = jnp.array([0.5, 1.0], dtype=jnp.float32)
    cfg = AdaptiveConfig(max_steps=32, initial_step=0.1)

    cases = {
        "sym": (lambda a, b: jnp.sum(
            odeint_symplectic(linear, tab, 6, "auto", x0, a, b, LIN_P)),
            (t0, t1)),
        "syma": (lambda a, b: jnp.sum(
            odeint_symplectic_adaptive(linear, tab, cfg, "auto",
                                       x0, a, b, LIN_P)), (t0, t1)),
        "adj": (lambda a, b: jnp.sum(
            odeint_adjoint(linear, tab, 6, 1, "auto", x0, a, b, LIN_P)),
            (t0, t1)),
        "sym_saveat": (lambda a, b: jnp.sum(
            odeint_symplectic_saveat(linear, tab, 4, "auto", x0, a, b,
                                     LIN_P)), (t0, ts32)),
        "syma_saveat": (lambda a, b: jnp.sum(
            odeint_symplectic_saveat_adaptive(linear, tab, cfg, "auto",
                                              x0, a, b, LIN_P)),
            (t0, ts32)),
    }
    for name, (loss, targs) in cases.items():
        gts = jax.grad(loss, argnums=(0, 1))(*targs)
        for g, t in zip(gts, targs):
            assert g.dtype == t.dtype, (name, g.dtype, t.dtype)
            assert g.shape == t.shape, (name, g.shape, t.shape)
            np.testing.assert_array_equal(np.asarray(g),
                                          np.zeros(t.shape, t.dtype))


def test_time_resolution_dtype_aware():
    t32 = _time_resolution(jnp.float32(0.0), jnp.float32(1000.0),
                           jnp.float32)
    assert float(t32) > np.spacing(np.float32(1000.0))
    t64 = _time_resolution(jnp.float64(0.0), jnp.float64(1.0), jnp.float64)
    assert float(t64) < 1e-14   # far tighter than the old fixed threshold


@pytest.mark.slow   # subprocess with its own jax init/compile
def test_float32_termination_no_attempt_burn():
    """With x64 disabled the eps-scaled threshold terminates cleanly on
    typical and offset intervals (the old 1e-14 is below f32 resolution)."""
    script = textwrap.dedent("""
        import jax, jax.numpy as jnp
        assert not jax.config.jax_enable_x64
        from repro.core import AdaptiveConfig, odeint_with_stats
        def f(x, t, p):
            return p["lam"] * x
        p = {"lam": jnp.asarray(-2.0)}
        cfg = AdaptiveConfig(rtol=1e-4, atol=1e-6, max_steps=256,
                             initial_step=0.05)
        for (a, b) in [(0.0, 1.0), (1000.0, 1001.0), (-0.5, 0.5)]:
            y, st = odeint_with_stats(f, jnp.ones(3), p, t0=a, t1=b,
                                      adaptive=cfg)
            assert bool(st["succeeded"]), (a, b)
            assert int(st["n_attempts"]) < 200, (a, b,
                                                 int(st["n_attempts"]))
            assert bool(jnp.all(jnp.isfinite(y))), (a, b)
        print("OK")
    """)
    env = dict(os.environ)
    env["JAX_ENABLE_X64"] = "0"
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout


def test_failure_flag_and_poisoning():
    cfg = AdaptiveConfig(rtol=1e-14, atol=1e-16, max_steps=4,
                         initial_step=0.01)
    x0 = jnp.asarray([1.0, 2.0])
    _, stats = odeint_with_stats(linear, x0, LIN_P, t1=1.0,
                                 method="dopri5", adaptive=cfg)
    assert not bool(stats["succeeded"])
    for mode in ADAPTIVE_MODES:
        y = odeint(linear, x0, LIN_P, t1=1.0, method="dopri5",
                   grad_mode=mode, adaptive=cfg)
        assert bool(jnp.all(jnp.isnan(y))), mode
    cfg_ok = AdaptiveConfig(rtol=1e-14, atol=1e-16, max_steps=4,
                            initial_step=0.01, on_failure="ignore")
    y = odeint(linear, x0, LIN_P, t1=1.0, method="dopri5",
               grad_mode="symplectic", adaptive=cfg_ok)
    assert bool(jnp.all(jnp.isfinite(y)))
    with pytest.raises(ValueError, match="on_failure"):
        AdaptiveConfig(on_failure="explode")


def test_failure_raise_policy():
    cfg = AdaptiveConfig(rtol=1e-14, atol=1e-16, max_steps=4,
                         initial_step=0.01, on_failure="raise")
    with pytest.raises(Exception, match="max_steps/max_attempts"):
        y = odeint(linear, jnp.ones(2), LIN_P, t1=1.0, method="dopri5",
                   grad_mode="backprop", adaptive=cfg)
        jax.block_until_ready(y)
