"""Trace-size regression tests for the scan-segmented SaveAt drivers.

The segmented drivers (``_segmented`` in core/odeint.py, the symplectic
SaveAt custom-VJP pair, ``rk_solve_adaptive_saveat_stacked``) run their
per-observation segments inside ``lax.scan``, so the traced program is ONE
segment body regardless of how many observation times the caller passes.
These tests pin that property down as a jaxpr *equation count* invariant:
growing ``len(ts)`` 8x may not grow the jaxpr by more than 10% — for the
forward value AND the full reverse-mode gradient of every gradient mode,
and for the component dimension of the CNF stack.

A regression back to Python-loop segmentation makes these counts linear in
``len(ts)`` (hundreds of percent, not <10%), so the bound is loose to
tracer-noise but tight to the failure mode.
"""
import jax
import jax.numpy as jnp
import pytest

jax.config.update("jax_enable_x64", True)

from repro.analysis import count_eqns
from repro.core import AdaptiveConfig, GRAD_MODES, odeint

# Deliberately exercises the deprecated odeint shim (shim regression suite).
pytestmark = pytest.mark.filterwarnings(
    "ignore:odeint-style entry point:DeprecationWarning")

ADAPTIVE_MODES = ["symplectic", "backprop", "adjoint"]


def mlp_field(x, t, params):
    h = jnp.tanh(params["w1"] @ x + params["b1"] + t)
    return params["w2"] @ h + params["b2"]


def make_params(key, dim=4, hidden=6):
    ks = jax.random.split(key, 4)
    return {
        "w1": jax.random.normal(ks[0], (hidden, dim)) * 0.5,
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(ks[2], (dim, hidden)) * 0.5,
        "b2": jnp.zeros((dim,)),
    }


PARAMS = make_params(jax.random.PRNGKey(0))
X0 = jnp.ones(4)


def _ts(n):
    return jnp.linspace(0.1, 1.0, n)


def _assert_flat(counts, context):
    c_small, c_big = counts
    assert c_big <= 1.1 * c_small, (
        f"{context}: jaxpr equation count grew {c_small} -> {c_big} "
        f"({c_big / c_small:.2f}x) when len(ts) grew 8x — the segmented "
        "driver is tracing per-observation again")


@pytest.mark.parametrize("mode", list(GRAD_MODES))
def test_fixed_grid_saveat_value_trace_flat(mode):
    def value(x0, params, n):
        return odeint(mlp_field, x0, params, ts=_ts(n), method="dopri5",
                      grad_mode=mode, n_steps=3)

    counts = [count_eqns(jax.make_jaxpr(
        lambda x, p: value(x, p, n))(X0, PARAMS).jaxpr) for n in (4, 32)]
    _assert_flat(counts, f"value[{mode}]")


@pytest.mark.parametrize("mode", list(GRAD_MODES))
def test_fixed_grid_saveat_grad_trace_flat(mode):
    def loss(x0, params, n):
        ys = odeint(mlp_field, x0, params, ts=_ts(n), method="dopri5",
                    grad_mode=mode, n_steps=3)
        return jnp.sum(jnp.sin(ys) ** 2)

    counts = [count_eqns(jax.make_jaxpr(jax.grad(
        lambda x, p: loss(x, p, n), argnums=(0, 1)))(X0, PARAMS).jaxpr)
        for n in (4, 32)]
    _assert_flat(counts, f"grad[{mode}]")


@pytest.mark.parametrize("mode", ADAPTIVE_MODES)
def test_adaptive_saveat_value_trace_flat(mode):
    cfg = AdaptiveConfig(max_steps=16, initial_step=0.05)

    def value(x0, params, n):
        return odeint(mlp_field, x0, params, ts=_ts(n), method="dopri5",
                      grad_mode=mode, adaptive=cfg)

    counts = [count_eqns(jax.make_jaxpr(
        lambda x, p: value(x, p, n))(X0, PARAMS).jaxpr) for n in (4, 32)]
    _assert_flat(counts, f"adaptive value[{mode}]")


@pytest.mark.parametrize("mode", ["symplectic", "adjoint"])
def test_adaptive_saveat_grad_trace_flat(mode):
    cfg = AdaptiveConfig(max_steps=16, initial_step=0.05)

    def loss(x0, params, n):
        ys = odeint(mlp_field, x0, params, ts=_ts(n), method="dopri5",
                    grad_mode=mode, adaptive=cfg)
        return jnp.sum(jnp.sin(ys) ** 2)

    counts = [count_eqns(jax.make_jaxpr(jax.grad(
        lambda x, p: loss(x, p, n), argnums=(0, 1)))(X0, PARAMS).jaxpr)
        for n in (4, 32)]
    _assert_flat(counts, f"adaptive grad[{mode}]")


def test_cnf_flow_path_trace_flat_in_components_and_ts():
    """The CNF stack scans over STACKED component params, and each
    component solve scans over observation segments: the flow-path trace is
    O(1) in both n_components and len(ts)."""
    from repro.models.cnf import CNFConfig, cnf_flow_path, init_cnf

    def build(m, n):
        cfg = CNFConfig(dim=3, hidden=(8,), n_components=m, n_steps=3,
                        trace="exact", method="bosh3")
        params = init_cnf(jax.random.PRNGKey(0), cfg)
        u = jnp.ones((2, 3))
        eps = jnp.ones((2, 3))
        return count_eqns(jax.make_jaxpr(
            lambda p: cnf_flow_path(p, u, eps, cfg, _ts(n)))(params).jaxpr)

    c_small = build(1, 4)
    c_big = build(8, 32)
    assert c_big <= 1.1 * c_small, (c_small, c_big)


def test_rollout_trace_flat_in_horizon():
    """physics.rollout horizons ride the scanned SaveAt path."""
    from repro.models.physics import PhysicsConfig, init_energy_net, rollout

    cfg = PhysicsConfig(grid=16, channels=4, hidden=8, method="bosh3",
                        n_steps=2)
    params = init_energy_net(jax.random.PRNGKey(0), cfg)
    u0 = jnp.ones((2, 16))

    def count(horizon):
        return count_eqns(jax.make_jaxpr(
            lambda p: rollout(p, u0, cfg, horizon))(params).jaxpr)

    assert count(64) <= 1.1 * count(4), (count(4), count(64))


def test_64_observation_rollout_compiles_and_grads():
    """A 64-observation symplectic SaveAt solve COMPILES (not just traces)
    within the CI budget and its gradient against a decimated reference is
    exact: the long-horizon capability the scan segmentation buys.  The
    unrolled drivers could not compile this in CI
    (benchmarks/bench_saveat_compile.py quantifies the wall-clock gap)."""
    ts64 = jnp.linspace(1.0 / 64, 1.0, 64)

    def loss(x0, params):
        ys = odeint(mlp_field, x0, params, ts=ts64, method="dopri5",
                    grad_mode="symplectic", n_steps=2)
        return jnp.sum(jnp.sin(ys) ** 2), ys

    (val, ys), grads = jax.jit(
        jax.value_and_grad(loss, argnums=(0, 1), has_aux=True))(X0, PARAMS)
    assert ys.shape == (64, 4)
    assert bool(jnp.all(jnp.isfinite(ys)))
    for g in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.all(jnp.isfinite(g)))
    # the observation at ts64[31] must equal a direct solve to that time
    # with the same accumulated grid (32 segments x 2 steps = 64 steps)
    import numpy as np
    y_direct = odeint(mlp_field, X0, PARAMS, ts=ts64[:32], method="dopri5",
                      grad_mode="backprop", n_steps=2)
    np.testing.assert_allclose(np.asarray(ys[31]), np.asarray(y_direct[-1]),
                               rtol=1e-12, atol=1e-14)
