"""Deterministic stand-in for ``hypothesis`` when it isn't installed.

The tier-1 suite must collect and pass on minimal containers that only have
jax + pytest (requirements.txt installs the real hypothesis in CI).  This
shim keeps the property tests RUNNING there — each ``@given`` test executes
a small fixed sweep of examples drawn deterministically from its strategies
(boundary values + a midpoint) instead of hypothesis's randomized search.
"""
from __future__ import annotations

import types


class _Strategy:
    def __init__(self, examples):
        self.examples = list(examples)


def _integers(min_value, max_value):
    mid = (min_value + max_value) // 2
    return _Strategy(sorted({min_value, mid, max_value}))


def _sampled_from(seq):
    return _Strategy(list(seq))


st = types.SimpleNamespace(integers=_integers, sampled_from=_sampled_from)


def settings(**_kwargs):
    """No-op settings decorator (max_examples/deadline have no meaning here)."""
    def deco(fn):
        return fn
    return deco


def given(**strategies):
    """Run the test once per example index, zipping strategy example lists
    (shorter lists repeat their last element)."""
    names = sorted(strategies)

    def deco(fn):
        def wrapper():
            n = max(len(strategies[k].examples) for k in names)
            for i in range(n):
                kwargs = {
                    k: strategies[k].examples[
                        min(i, len(strategies[k].examples) - 1)]
                    for k in names
                }
                fn(**kwargs)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
