"""mLSTM evaluation forms: chunkwise-recurrent == parallel (values, grads,
carry states) — the §Perf Cell-A machinery must be exact, not approximate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.xlstm import (XLSTMConfig, init_mlstm, init_mlstm_state,
                            mlstm_forward)


def _setup(S=64, d=64):
    cfgP = XLSTMConfig(d_model=d, n_heads=4, m_form="parallel")
    cfgC = XLSTMConfig(d_model=d, n_heads=4, m_form="chunkwise", m_chunk=16)
    p = init_mlstm(jax.random.PRNGKey(0), cfgP)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, d)) * 0.5
    return cfgP, cfgC, p, x


def test_chunkwise_matches_parallel_values():
    cfgP, cfgC, p, x = _setup()
    yp, _ = mlstm_forward(p, x, cfgP)
    yc, _ = mlstm_forward(p, x, cfgC)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yc),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.slow
def test_chunkwise_matches_parallel_grads():
    cfgP, cfgC, p, x = _setup()

    def loss(pp, cfg):
        return jnp.sum(mlstm_forward(pp, x, cfg)[0] ** 2)

    gp = jax.grad(loss)(p, cfgP)
    gc = jax.grad(loss)(p, cfgC)
    for a, b in zip(jax.tree_util.tree_leaves(gp),
                    jax.tree_util.tree_leaves(gc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4)


@pytest.mark.slow
def test_chunkwise_carry_matches_recurrent_decode():
    """The chunkwise final carry equals rolling the O(1) decode recurrence
    token by token — so prefill->decode handoff is consistent."""
    cfgP, cfgC, p, x = _setup(S=48)
    st0 = init_mlstm_state(cfgC, 2)
    _, stC = mlstm_forward(p, x, cfgC, state=st0)
    st = init_mlstm_state(cfgP, 2)
    cfg1 = XLSTMConfig(d_model=64, n_heads=4)
    for t in range(48):
        _, st = mlstm_forward(p, x[:, t:t + 1], cfg1, state=st)
    for k in ("C", "n", "m"):
        np.testing.assert_allclose(np.asarray(stC[k]), np.asarray(st[k]),
                                   rtol=5e-3, atol=5e-4)


@pytest.mark.slow
def test_auto_form_switches_on_length():
    cfg = XLSTMConfig(d_model=32, n_heads=4, m_form="auto", m_chunk=16,
                      m_chunkwise_min_s=64)
    p = init_mlstm(jax.random.PRNGKey(0), cfg)
    for S in (32, 64):   # below / at the threshold — both must be finite
        x = jax.random.normal(jax.random.PRNGKey(S), (1, S, 32))
        y, _ = mlstm_forward(p, x, cfg)
        assert bool(jnp.all(jnp.isfinite(y)))
