"""Runtime tests: checkpoint/restore, crash-resume, elastic resharding,
gradient compression, retry logic, schedules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (CompressionConfig, compress_grads,
                         decompress_grads, cosine_schedule, wsd_schedule)
from repro.optim.compress import init_error_state
from repro.runtime import Checkpointer, RetryConfig, run_with_retries


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros(8)},
            "opt": {"m": {"w": jnp.ones((8, 8)), "b": jnp.ones(8)},
                    "step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    s = _state()
    ck.save(3, s)
    restored, step = ck.restore(s)
    assert step == 3
    for a, b in zip(jax.tree_util.tree_leaves(s),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    s = _state()
    for step in (1, 2, 3, 4):
        ck.save(step, s)
    assert ck.list_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3, async_save=True)
    s = _state()
    ck.save(1, s, block=False)
    ck.wait()
    assert ck.latest_step() == 1


def test_checkpoint_ignores_partial(tmp_path):
    """A directory without MANIFEST (simulated crash mid-write) is not a
    valid checkpoint."""
    ck = Checkpointer(str(tmp_path))
    s = _state()
    ck.save(1, s)
    os.makedirs(tmp_path / "step_2")  # corrupt: no manifest
    (tmp_path / "step_2" / "host_0.npz").write_bytes(b"garbage")
    assert ck.latest_step() == 1
    restored, step = ck.restore(s)
    assert step == 1


def test_checkpoint_sweeps_stale_tmp_dirs(tmp_path):
    """``.tmp_step_*`` leftovers from a crash mid-write are invisible to
    restore AND swept on init / before the next save (a crash loop must
    not leak disk)."""
    ck = Checkpointer(str(tmp_path))
    s = _state()
    ck.save(1, s)
    stale = tmp_path / ".tmp_step_9_0"
    stale.mkdir()
    (stale / "host_0.npz").write_bytes(b"half-written")
    # invisible to discovery
    assert ck.list_steps() == [1]
    # a new Checkpointer (= process restart) sweeps it
    ck2 = Checkpointer(str(tmp_path))
    assert not stale.exists()
    # and a save through an EXISTING instance sweeps before writing
    stale.mkdir()
    ck2.save(2, s)
    assert not stale.exists()
    assert ck2.list_steps() == [1, 2]


def test_checkpoint_restore_rejects_wrong_leaf_count(tmp_path):
    """Restoring with a template whose pytree doesn't match what was saved
    is a clear shape-contract error, not a bare KeyError from npz."""
    ck = Checkpointer(str(tmp_path))
    s = _state()
    ck.save(1, s)
    wrong = dict(s)
    wrong["params"] = dict(s["params"], extra=jnp.zeros(3))
    with pytest.raises(ValueError, match="shape-contract mismatch"):
        ck.restore(wrong)


def test_retry_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    out = run_with_retries(flaky, RetryConfig(max_retries=5, backoff_s=0.0))
    assert out == "ok" and calls["n"] == 3


def test_retry_gives_up():
    def always():
        raise RuntimeError("permanent")

    with pytest.raises(RuntimeError):
        run_with_retries(always, RetryConfig(max_retries=2, backoff_s=0.0))


@pytest.mark.parametrize("mode", ["bf16", "int8"])
def test_compression_roundtrip_accuracy(mode):
    cfg = CompressionConfig(mode=mode)
    g = {"a": jax.random.normal(jax.random.PRNGKey(0), (64, 64)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (128,)) * 1e-3}
    err = init_error_state(g, cfg)
    comp, err = compress_grads(g, cfg, err)
    out = decompress_grads(comp, cfg)
    for k in g:
        rel = float(jnp.linalg.norm(out[k] - g[k]) /
                    jnp.linalg.norm(g[k]))
        assert rel < (2e-2 if mode == "bf16" else 2e-2), (k, rel)


def test_int8_error_feedback_reduces_bias():
    """With error feedback, the accumulated compressed sum converges to the
    true sum (1-bit-Adam-style argument)."""
    cfg = CompressionConfig(mode="int8", error_feedback=True)
    g = {"a": jnp.full((32,), 0.001)}
    err = init_error_state(g, cfg)
    total = jnp.zeros(32)
    for _ in range(50):
        comp, err = compress_grads(g, cfg, err)
        total = total + decompress_grads(comp, cfg)["a"]
    np.testing.assert_allclose(np.asarray(total), 0.05, rtol=0.05)


def test_schedules():
    cs = cosine_schedule(1.0, warmup=10, total=100)
    assert float(cs(jnp.int32(0))) == 0.0
    assert abs(float(cs(jnp.int32(10))) - 1.0) < 1e-6
    assert float(cs(jnp.int32(100))) < 0.2
    ws = wsd_schedule(1.0, warmup=10, stable=50, decay=40)
    assert abs(float(ws(jnp.int32(30))) - 1.0) < 1e-6
    assert float(ws(jnp.int32(100))) < 0.05


def test_elastic_reshard_cpu():
    """Restoring onto a different device layout: single-device roundtrip
    via explicit shardings (the multi-chip path is the same code)."""
    from repro.runtime import reshard_state
    from repro.launch.mesh import make_mesh_compat
    from jax.sharding import PartitionSpec as P
    mesh = make_mesh_compat((1,), ("data",))
    s = _state()
    specs = jax.tree_util.tree_map(lambda _: P(), s)
    out = reshard_state(s, mesh, specs)
    for a, b in zip(jax.tree_util.tree_leaves(s),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow   # two full driver runs with checkpoint IO
def test_train_driver_crash_resume(tmp_path):
    """End-to-end fault tolerance: run the driver with an injected failure
    and a checkpoint dir; it must complete and produce checkpoints."""
    from repro.launch import train as train_mod
    train_mod.main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "12",
                    "--global-batch", "4", "--seq-len", "32",
                    "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
                    "--fail-at-step", "7"])
    ck = Checkpointer(str(tmp_path))
    assert ck.latest_step() == 12
    # resume from the checkpoint (elastic restart path)
    train_mod.main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "14",
                    "--global-batch", "4", "--seq-len", "32",
                    "--ckpt-dir", str(tmp_path)])
    assert Checkpointer(str(tmp_path)).latest_step() == 14
