"""Docs drift guards.

The capability-matrix tables rendered in docs/api.md are GENERATED from
``repro.core.capability_matrix()`` / ``batched_capability_matrix()`` by
tools/gen_capability_table.py; these tests fail when the committed tables
drift from the registry, when any relative markdown link in docs/ or the
README is dead, or when a docs page is missing from the docs/README.md
index.  The CI docs lane runs the same checks via the tools' CLIs.
"""
import importlib.util
import pathlib

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _load_tool(name):
    spec = importlib.util.spec_from_file_location(
        name, REPO_ROOT / "tools" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_capability_matrix_table_matches_registry():
    gen = _load_tool("gen_capability_table")
    assert gen.committed_block() == gen.render_block(), (
        "docs/api.md capability matrix drifted from the strategy registry; "
        "run: PYTHONPATH=src python tools/gen_capability_table.py --write")


def test_no_dead_relative_links_in_docs_or_readme():
    chk = _load_tool("check_docs_links")
    assert chk.find_dead_links(REPO_ROOT) == []


def test_every_docs_page_reachable_from_docs_index():
    chk = _load_tool("check_docs_links")
    assert chk.find_unreachable_docs(REPO_ROOT) == []


def test_docs_index_covers_the_expected_pages():
    # the five design pages the docs system is built around
    docs = {p.name for p in (REPO_ROOT / "docs").glob("*.md")}
    assert {"README.md", "api.md", "adaptive.md", "batching.md",
            "gradients.md", "stage_combine.md"} <= docs
