"""Composable solve() API tests.

Covers the api_redesign acceptance criteria:
  * ``Solution`` is a well-behaved pytree: round-trips through ``jit``,
    ``vmap`` over batched x0, and ``jax.grad`` of losses on ``sol.ys``;
  * golden equivalence: the ``odeint`` / ``odeint_with_stats`` shims pin
    EXACTLY (values and stats dicts) to the pre-redesign behavior — i.e.
    to the unchanged underlying drivers and the historical stats formulas —
    for all five gradient modes on fixed and adaptive grids;
  * a new gradient strategy registers and solves WITHOUT editing solve();
  * the declarative capability matrix rejects every illegal combination
    with a uniform error;
  * the satellite validations: eager ts-monotonicity rejection and
    ContinuousAdjoint.steps_multiplier >= 1 (also via the legacy kwarg).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import (AdaptiveConfig, ContinuousAdjoint, DirectBackprop,
                        GRAD_MODES, RematSolve, RematStep, SaveAt, Solution,
                        SymplecticAdjoint, apply_on_failure, as_gradient,
                        capability_matrix, get_tableau, hermite_observe,
                        odeint, odeint_adjoint, odeint_adjoint_adaptive,
                        odeint_backprop, odeint_remat_solve,
                        odeint_remat_step, odeint_symplectic,
                        odeint_symplectic_adaptive, odeint_symplectic_saveat,
                        odeint_symplectic_saveat_adaptive, odeint_with_stats,
                        register_gradient, rk_solve_adaptive, solve)
from repro.core import api as api_mod


def mlp_field(x, t, params):
    h = jnp.tanh(params["w1"] @ x + params["b1"] + t)
    return params["w2"] @ h + params["b2"]


def make_params(key, dim=4, hidden=6):
    ks = jax.random.split(key, 4)
    return {
        "w1": jax.random.normal(ks[0], (hidden, dim)) * 0.5,
        "b1": jax.random.normal(ks[1], (hidden,)) * 0.1,
        "w2": jax.random.normal(ks[2], (dim, hidden)) * 0.5,
        "b2": jax.random.normal(ks[3], (dim,)) * 0.1,
    }


PARAMS = make_params(jax.random.PRNGKey(0))
X0 = jax.random.normal(jax.random.PRNGKey(1), (4,))
TS3 = jnp.array([0.25, 0.5, 0.875])
CFG = AdaptiveConfig(rtol=1e-6, atol=1e-8, max_steps=64, initial_step=0.05)
TAB = get_tableau("dopri5")


def assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def shim_odeint(*args, **kwargs):
    with pytest.warns(DeprecationWarning, match="odeint-style"):
        return odeint(*args, **kwargs)


def shim_with_stats(*args, **kwargs):
    with pytest.warns(DeprecationWarning, match="odeint-style"):
        return odeint_with_stats(*args, **kwargs)


# --- Solution as a pytree ----------------------------------------------------

def test_solution_jit_round_trip():
    def run(x0):
        return solve(mlp_field, x0, PARAMS, stepping=6)

    sol = run(X0)
    jsol = jax.jit(run)(X0)
    assert isinstance(jsol, Solution)
    np.testing.assert_allclose(np.asarray(jsol.ys), np.asarray(sol.ys),
                               rtol=1e-14)  # jit may refuse by 1 ulp
    assert_trees_equal(sol.stats, jsol.stats)
    assert bool(jsol.success)
    # flatten/unflatten identity
    leaves, treedef = jax.tree_util.tree_flatten(sol)
    sol2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(sol2, Solution)
    assert_trees_equal(sol.final_state, sol2.final_state)


@pytest.mark.parametrize("stepping", [6, CFG], ids=["fixed", "adaptive"])
def test_solution_vmap_batched_x0(stepping):
    xb = jnp.stack([X0, 2.0 * X0, -X0])
    vsol = jax.vmap(lambda x: solve(mlp_field, x, PARAMS,
                                    stepping=stepping))(xb)
    assert vsol.ys.shape == (3, 4)
    assert vsol.stats["n_steps"].shape == (3,)
    assert vsol.success.shape == (3,)
    for i in range(3):
        one = solve(mlp_field, xb[i], PARAMS, stepping=stepping)
        np.testing.assert_allclose(np.asarray(vsol.ys[i]),
                                   np.asarray(one.ys), rtol=1e-12)
        assert int(vsol.stats["n_steps"][i]) == int(one.stats["n_steps"])


def test_solution_grad_on_ys():
    def loss(x0, params, gradient):
        sol = solve(mlp_field, x0, params, saveat=SaveAt(ts=TS3),
                    gradient=gradient, stepping=5)
        return jnp.sum(jnp.sin(sol.ys) ** 2)

    g_sym = jax.grad(loss, argnums=(0, 1))(X0, PARAMS, SymplecticAdjoint())
    g_ref = jax.grad(loss, argnums=(0, 1))(X0, PARAMS, DirectBackprop())
    for a, b in zip(jax.tree_util.tree_leaves(g_sym),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-10, atol=1e-12)


def test_solution_stats_fixed_are_static_counts():
    sol = solve(mlp_field, X0, PARAMS, method="dopri5", stepping=7)
    assert int(sol.stats["n_steps"]) == 7
    assert int(sol.stats["n_fevals"]) == 7 * TAB.s
    assert int(sol.stats["n_attempts"]) == 7
    sol = solve(mlp_field, X0, PARAMS, saveat=SaveAt(ts=TS3), stepping=4)
    assert int(sol.stats["n_steps"]) == 3 * 4
    assert_trees_equal(sol.final_state, sol.ys[-1])


def test_solution_stats_adaptive_match_controller():
    ref = rk_solve_adaptive(mlp_field, TAB, X0, jnp.asarray(0.0), 1.0,
                            PARAMS, CFG)
    for gradient in (SymplecticAdjoint(), DirectBackprop(),
                     ContinuousAdjoint()):
        sol = solve(mlp_field, X0, PARAMS, gradient=gradient, stepping=CFG)
        assert int(sol.stats["n_steps"]) == int(ref.n_accepted)
        assert int(sol.stats["n_fevals"]) == int(ref.n_fevals)
        assert int(sol.stats["n_attempts"]) == int(ref.n_attempts)
        assert bool(sol.success)


# --- golden equivalence: shims == pre-redesign drivers -----------------------

FIXED_DRIVERS = {
    "symplectic": lambda n, x, t0, t1: odeint_symplectic(
        mlp_field, TAB, n, "auto", x, t0, t1, PARAMS),
    "backprop": lambda n, x, t0, t1: odeint_backprop(
        mlp_field, TAB, n, x, t0, t1, PARAMS, "auto"),
    "remat_step": lambda n, x, t0, t1: odeint_remat_step(
        mlp_field, TAB, n, x, t0, t1, PARAMS, "auto"),
    "remat_solve": lambda n, x, t0, t1: odeint_remat_solve(
        mlp_field, TAB, n, x, t0, t1, PARAMS, "auto"),
    "adjoint": lambda n, x, t0, t1: odeint_adjoint(
        mlp_field, TAB, n, 1, "auto", x, t0, t1, PARAMS),
}


@pytest.mark.parametrize("mode", list(GRAD_MODES))
def test_golden_fixed_t1(mode):
    y = shim_odeint(mlp_field, X0, PARAMS, t1=1.0, method="dopri5",
                    grad_mode=mode, n_steps=6)
    t0 = jnp.asarray(0.0)
    ref = FIXED_DRIVERS[mode](6, X0, t0, jnp.asarray(1.0))
    assert_trees_equal(y, ref)
    # and the new entry point is the same map
    sol = solve(mlp_field, X0, PARAMS, saveat=SaveAt(t1=1.0),
                gradient=mode, stepping=6)
    assert_trees_equal(y, sol.ys)
    assert_trees_equal(sol.ys, sol.final_state)


@pytest.mark.parametrize("mode", list(GRAD_MODES))
def test_golden_fixed_ts_segmented(mode):
    ys = shim_odeint(mlp_field, X0, PARAMS, ts=TS3, method="dopri5",
                     grad_mode=mode, n_steps=4)
    if mode == "symplectic":
        ref = odeint_symplectic_saveat(mlp_field, TAB, 4, "auto", X0,
                                       jnp.asarray(0.0), TS3, PARAMS)
        assert_trees_equal(ys, ref)
    else:
        # pre-redesign: chained per-segment driver solves
        x, t_prev = X0, jnp.asarray(0.0)
        for i in range(3):
            x = FIXED_DRIVERS[mode](4, x, t_prev, TS3[i])
            np.testing.assert_allclose(np.asarray(ys[i]), np.asarray(x),
                                       rtol=1e-12, atol=1e-14)
            t_prev = TS3[i]


@pytest.mark.parametrize("mode", ["symplectic", "backprop", "adjoint"])
def test_golden_adaptive_t1(mode):
    y = shim_odeint(mlp_field, X0, PARAMS, t1=1.0, method="dopri5",
                    grad_mode=mode, adaptive=CFG)
    t0, t1 = jnp.asarray(0.0), jnp.asarray(1.0)
    if mode == "symplectic":
        ref = odeint_symplectic_adaptive(mlp_field, TAB, CFG, "auto",
                                         X0, t0, t1, PARAMS)
    elif mode == "adjoint":
        ref = odeint_adjoint_adaptive(mlp_field, TAB, CFG, CFG, "auto",
                                      X0, t0, t1, PARAMS)
    else:
        sol = rk_solve_adaptive(mlp_field, TAB, X0, t0, t1, PARAMS, CFG)
        ref = apply_on_failure(sol.x_final, sol.succeeded, CFG.on_failure)
    assert_trees_equal(y, ref)


@pytest.mark.parametrize("mode", ["symplectic", "backprop", "adjoint"])
def test_golden_adaptive_ts(mode):
    ys = shim_odeint(mlp_field, X0, PARAMS, ts=TS3, method="dopri5",
                     grad_mode=mode, adaptive=CFG)
    assert ys.shape == (3, 4)
    if mode == "symplectic":
        ref = odeint_symplectic_saveat_adaptive(
            mlp_field, TAB, CFG, "auto", X0, jnp.asarray(0.0), TS3, PARAMS)
        assert_trees_equal(ys, ref)
    elif mode == "adjoint":
        # pre-redesign: per-segment odeint_adjoint_adaptive (controller
        # RESTARTS at each observation boundary)
        x, t_prev = X0, jnp.asarray(0.0)
        for i in range(3):
            x = odeint_adjoint_adaptive(mlp_field, TAB, CFG, CFG, "auto",
                                        x, t_prev, TS3[i], PARAMS)
            np.testing.assert_allclose(np.asarray(ys[i]), np.asarray(x),
                                       rtol=1e-12, atol=1e-14)
            t_prev = TS3[i]


def test_golden_symplectic_gradient_through_shim():
    def loss_shim(x0, params):
        y = odeint(mlp_field, x0, params, t1=1.0, method="dopri5",
                   grad_mode="symplectic", n_steps=6)
        return jnp.sum(jnp.tanh(y) ** 2)

    def loss_driver(x0, params):
        y = odeint_symplectic(mlp_field, TAB, 6, "auto", x0,
                              jnp.asarray(0.0), jnp.asarray(1.0), params)
        return jnp.sum(jnp.tanh(y) ** 2)

    with pytest.warns(DeprecationWarning, match="odeint-style"):
        g_shim = jax.grad(loss_shim, argnums=(0, 1))(X0, PARAMS)
    g_drv = jax.grad(loss_driver, argnums=(0, 1))(X0, PARAMS)
    assert_trees_equal(g_shim, g_drv)


def test_golden_with_stats_fixed():
    y, stats = shim_with_stats(mlp_field, X0, PARAMS, t1=1.0,
                               method="dopri5", n_steps=5)
    assert sorted(stats) == ["n_fevals", "n_steps"]
    assert int(stats["n_steps"]) == 5
    assert int(stats["n_fevals"]) == 5 * TAB.s
    assert_trees_equal(y, FIXED_DRIVERS["backprop"](
        5, X0, jnp.asarray(0.0), jnp.asarray(1.0)))

    ys, stats = shim_with_stats(mlp_field, X0, PARAMS, ts=TS3,
                                method="dopri5", n_steps=5)
    assert sorted(stats) == ["n_fevals", "n_steps"]
    assert int(stats["n_steps"]) == 3 * 5
    assert int(stats["n_fevals"]) == 3 * 5 * TAB.s


def test_golden_with_stats_adaptive():
    y, stats = shim_with_stats(mlp_field, X0, PARAMS, t1=1.0,
                               method="dopri5", adaptive=CFG)
    sol = rk_solve_adaptive(mlp_field, TAB, X0, jnp.asarray(0.0), 1.0,
                            PARAMS, CFG)
    assert sorted(stats) == ["n_attempts", "n_fevals", "n_steps",
                             "succeeded"]
    assert int(stats["n_steps"]) == int(sol.n_accepted)
    assert int(stats["n_fevals"]) == int(sol.n_fevals)
    assert int(stats["n_attempts"]) == int(sol.n_attempts)
    assert bool(stats["succeeded"])
    assert_trees_equal(y, sol.x_final)


def test_golden_with_stats_adaptive_ts_is_dense():
    ys, stats = shim_with_stats(mlp_field, X0, PARAMS, ts=TS3,
                                method="dopri5", adaptive=CFG)
    sol = rk_solve_adaptive(mlp_field, TAB, X0, jnp.asarray(0.0), TS3[-1],
                            PARAMS, CFG)
    ref = hermite_observe(mlp_field, TAB, sol, PARAMS, TS3)
    assert_trees_equal(ys, ref)
    assert int(stats["n_steps"]) == int(sol.n_accepted)
    assert int(stats["n_fevals"]) == int(sol.n_fevals) + 2 * 3


def test_golden_with_stats_failure_not_poisoned():
    # historical contract: with_stats NEVER poisons/raises — failure is
    # reported via stats["succeeded"], even when the config says otherwise.
    tight = AdaptiveConfig(rtol=1e-14, atol=1e-16, max_steps=4,
                           initial_step=0.01, on_failure="nan")
    y, stats = shim_with_stats(mlp_field, X0, PARAMS, t1=1.0,
                               method="dopri5", adaptive=tight)
    assert not bool(stats["succeeded"])
    assert bool(jnp.all(jnp.isfinite(y)))


# --- extensible gradient registry --------------------------------------------

def test_register_toy_strategy_without_editing_solve():
    @register_gradient
    @dataclasses.dataclass(frozen=True)
    class ToyDoubleSteps(api_mod.GradientStrategy):
        """Backprop with a doubled step budget — three lines of hooks."""
        name = "toy_double"
        capabilities = frozenset({("fixed", "t1"), ("fixed", "ts")})

        def fixed(self, ctx, x0, t0, t1, params):
            return odeint_backprop(ctx.f, ctx.tab, 2 * ctx.n_steps,
                                   x0, t0, t1, params, ctx.backend)

    try:
        sol = solve(mlp_field, X0, PARAMS, gradient="toy_double",
                    stepping=3)
        ref = solve(mlp_field, X0, PARAMS, gradient="backprop", stepping=6)
        assert_trees_equal(sol.ys, ref.ys)
        # the default SaveAt segmentation comes for free
        sol = solve(mlp_field, X0, PARAMS, saveat=SaveAt(ts=TS3),
                    gradient=ToyDoubleSteps(), stepping=3)
        assert sol.ys.shape == (3, 4)
        # and the capability matrix guards the cells it did not claim
        with pytest.raises(ValueError, match="toy_double"):
            solve(mlp_field, X0, PARAMS, gradient="toy_double",
                  stepping=CFG)
        assert "toy_double" in capability_matrix()
    finally:
        del api_mod.GRADIENT_REGISTRY["toy_double"]


def test_minimal_adaptive_strategy_stats_match_its_own_solve():
    """A strategy implementing ONLY adaptive() gets SaveAt values from the
    default restart-per-segment segmentation — and the default stats
    replay must describe that same restarting sequence, not a threaded
    one."""
    @register_gradient
    @dataclasses.dataclass(frozen=True)
    class ToyAdaptive(api_mod.GradientStrategy):
        name = "toy_adaptive"
        capabilities = frozenset({("adaptive", "t1"), ("adaptive", "ts")})

        def adaptive(self, ctx, x0, t0, t1, params):
            sol = rk_solve_adaptive(ctx.f, ctx.tab, x0, t0, t1, params,
                                    ctx.adaptive, ctx.backend)
            return apply_on_failure(sol.x_final, sol.succeeded,
                                    ctx.adaptive.on_failure)

    try:
        sol = solve(mlp_field, X0, PARAMS, saveat=SaveAt(ts=TS3),
                    gradient="toy_adaptive", stepping=CFG)
        # reference: replay the restarting segmentation by hand
        x, t_prev, n_acc = X0, jnp.asarray(0.0), 0
        for i in range(3):
            seg = rk_solve_adaptive(mlp_field, TAB, x, t_prev, TS3[i],
                                    PARAMS, CFG)
            x, t_prev, n_acc = seg.x_final, TS3[i], n_acc + int(seg.n_accepted)
            np.testing.assert_allclose(np.asarray(sol.ys[i]),
                                       np.asarray(x), rtol=1e-12)
        assert int(sol.stats["n_steps"]) == n_acc
        assert bool(sol.success)
    finally:
        del api_mod.GRADIENT_REGISTRY["toy_adaptive"]


def test_as_gradient_spec_forms():
    assert isinstance(as_gradient("symplectic"), SymplecticAdjoint)
    assert isinstance(as_gradient(DirectBackprop), DirectBackprop)
    adj = ContinuousAdjoint(steps_multiplier=3)
    assert as_gradient(adj) is adj
    with pytest.raises(ValueError, match="unknown gradient strategy"):
        as_gradient("nope")
    with pytest.raises(TypeError):
        as_gradient(42)


# --- capability matrix -------------------------------------------------------

def test_capability_matrix_shape_and_errors():
    mat = capability_matrix()
    for name in GRAD_MODES:
        assert name in mat
        assert len(mat[name]) == 6  # 2 steppings x 3 saveat kinds
        assert not mat[name][("fixed", "dense")]  # dense needs a controller
    assert mat["backprop"][("adaptive", "dense")]
    for bad_gradient, stepping, saveat in [
            (RematStep(), CFG, None),
            (RematSolve(), CFG, None),
            (RematStep(), CFG, SaveAt(ts=TS3)),
            (SymplecticAdjoint(), CFG, SaveAt(ts=TS3, dense=True)),
            (DirectBackprop(), 4, SaveAt(ts=TS3, dense=True))]:
        with pytest.raises(ValueError,
                           match="legal .stepping.saveat. combinations"):
            solve(mlp_field, X0, PARAMS, saveat=saveat,
                  gradient=bad_gradient, stepping=stepping)


def test_stepping_validation():
    with pytest.raises(ValueError, match="needs >= 1 steps"):
        solve(mlp_field, X0, PARAMS, stepping=0)
    with pytest.raises(TypeError, match="stepping must be"):
        solve(mlp_field, X0, PARAMS, stepping="adaptive")


def test_saveat_validation():
    with pytest.raises(ValueError, match="EITHER t1 or ts"):
        SaveAt(t1=1.0, ts=TS3)
    with pytest.raises(ValueError, match="one of t1"):
        SaveAt()
    with pytest.raises(ValueError, match="dense"):
        SaveAt(t1=1.0, dense=True)
    with pytest.raises(ValueError, match="EITHER t1 or ts"):
        shim_odeint(mlp_field, X0, PARAMS, t1=1.0, ts=TS3)


# --- satellite: ts monotonicity contract -------------------------------------

def test_ts_rejects_descending_against_direction():
    # forward t0 but descending ts: direction flips mid-solve
    with pytest.raises(ValueError, match="monotone"):
        solve(mlp_field, X0, PARAMS, saveat=SaveAt(ts=jnp.array(
            [0.875, 0.5, 0.25])), stepping=4, t0=0.0)


def test_ts_rejects_shuffled():
    for bad in ([0.5, 0.25, 0.875], [0.25, 0.875, 0.5]):
        with pytest.raises(ValueError, match="monotone"):
            solve(mlp_field, X0, PARAMS, saveat=SaveAt(ts=jnp.array(bad)),
                  stepping=4)
        with pytest.raises(ValueError, match="monotone"):
            shim_odeint(mlp_field, X0, PARAMS, ts=jnp.array(bad), n_steps=4)


def test_ts_allows_duplicates_and_reverse_time():
    sol = solve(mlp_field, X0, PARAMS,
                saveat=SaveAt(ts=jnp.array([0.5, 0.5, 1.0])), stepping=4)
    assert_trees_equal(sol.ys[0], sol.ys[1])
    sol = solve(mlp_field, X0, PARAMS,
                saveat=SaveAt(ts=jnp.array([0.6, 0.3, 0.0])), stepping=4,
                t0=1.0)
    assert sol.ys.shape == (3, 4)


def test_ts_tracer_passes_through():
    # non-concrete ts cannot be validated at trace time; the solve must
    # still trace and run (the contract is on the caller).
    ys = jax.jit(lambda ts: solve(mlp_field, X0, PARAMS,
                                  saveat=SaveAt(ts=ts),
                                  stepping=4).ys)(TS3)
    ref = solve(mlp_field, X0, PARAMS, saveat=SaveAt(ts=TS3), stepping=4).ys
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ref), rtol=1e-12)


# --- satellite: ContinuousAdjoint.steps_multiplier >= 1 ----------------------

def test_adjoint_steps_multiplier_validation():
    with pytest.raises(ValueError, match="steps_multiplier"):
        ContinuousAdjoint(steps_multiplier=0)
    with pytest.raises(ValueError, match="steps_multiplier"):
        ContinuousAdjoint(steps_multiplier=-2)
    assert ContinuousAdjoint(steps_multiplier=2).steps_multiplier == 2
    # numpy integers (configs, loaded arrays) are normalized, like
    # solve()'s stepping
    adj = ContinuousAdjoint(steps_multiplier=np.int64(2))
    assert adj.steps_multiplier == 2 and type(adj.steps_multiplier) is int
    with pytest.raises(ValueError, match="steps_multiplier"):
        ContinuousAdjoint(steps_multiplier=np.int64(0))
    # the legacy kwarg funnels through the same check
    with pytest.raises(ValueError, match="steps_multiplier"):
        shim_odeint(mlp_field, X0, PARAMS, t1=1.0, grad_mode="adjoint",
                    adjoint_steps_multiplier=0)
    # historical contract: the adjoint-only kwargs are ignored by other
    # modes, so a bogus multiplier must NOT trip them
    y = shim_odeint(mlp_field, X0, PARAMS, t1=1.0, grad_mode="symplectic",
                    adjoint_steps_multiplier=0, n_steps=4)
    assert bool(jnp.all(jnp.isfinite(y)))


# --- deprecation surface -----------------------------------------------------

def test_shims_warn_deprecation():
    with pytest.warns(DeprecationWarning, match="repro.core.solve"):
        odeint(mlp_field, X0, PARAMS, t1=1.0, n_steps=2)
    with pytest.warns(DeprecationWarning, match="repro.core.solve"):
        odeint_with_stats(mlp_field, X0, PARAMS, t1=1.0, n_steps=2)
