"""Core library tests: RK order, gradient exactness, adjoint inexactness.

The central claim of the paper — the symplectic adjoint returns the EXACT
gradient of the discrete forward map (up to rounding) for ANY explicit RK
tableau, including those with b_i = 0 stages — is verified here against
jax.grad through the unrolled solver in float64.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import (AdaptiveConfig, TABLEAUS, get_tableau, odeint,
                        odeint_with_stats)

# This module deliberately exercises the deprecated odeint shims — it doubles
# as the shim's regression suite (values must match solve() bit-for-bit; see
# tests/test_api.py for the golden-equivalence checks).
pytestmark = pytest.mark.filterwarnings(
    "ignore:odeint-style entry point:DeprecationWarning")

ALL_METHODS = sorted(TABLEAUS)
ADAPTIVE_METHODS = [n for n in ALL_METHODS if TABLEAUS[n].b_err is not None]


# --- test vector fields ------------------------------------------------------

def linear_field(x, t, params):
    return params["A"] @ x + params["b"] * jnp.sin(t)


def mlp_field(x, t, params):
    h = jnp.tanh(params["w1"] @ x + params["b1"] + t)
    return params["w2"] @ h + params["b2"]


def pytree_field(state, t, params):
    x, v = state
    return (v, -params["k"] * x - params["c"] * v)


def make_params(key, dim=5, hidden=8):
    ks = jax.random.split(key, 6)
    return {
        "A": jax.random.normal(ks[0], (dim, dim)) * 0.3,
        "b": jax.random.normal(ks[1], (dim,)),
        "w1": jax.random.normal(ks[2], (hidden, dim)) * 0.5,
        "b1": jax.random.normal(ks[3], (hidden,)) * 0.1,
        "w2": jax.random.normal(ks[4], (dim, hidden)) * 0.5,
        "b2": jax.random.normal(ks[5], (dim,)) * 0.1,
        "k": jnp.asarray(1.7), "c": jnp.asarray(0.3),
    }


# --- convergence order -------------------------------------------------------

@pytest.mark.parametrize("method", ALL_METHODS)
def test_rk_convergence_order(method):
    """Each tableau converges at (at least) its nominal order on a smooth ODE."""
    tab = get_tableau(method)
    params = {"lam": jnp.asarray(-0.7)}

    def f(x, t, p):
        return p["lam"] * x

    x0 = jnp.asarray([1.0])
    exact = x0 * jnp.exp(params["lam"] * 1.0)
    errs = []
    ns = [4, 8] if tab.order >= 8 else [8, 16]
    for n in ns:
        y = odeint(f, x0, params, t0=0.0, t1=1.0, method=method,
                   grad_mode="backprop", n_steps=n)
        errs.append(float(jnp.abs(y - exact)[0]))
    if errs[1] < 1e-14:  # already at rounding floor
        return
    rate = np.log2(errs[0] / errs[1])
    assert rate > tab.order - 0.55, (method, errs, rate)


# --- gradient exactness (THE paper claim) ------------------------------------

@pytest.mark.parametrize("method", ALL_METHODS)
@pytest.mark.parametrize("field", ["linear", "mlp"])
def test_symplectic_gradient_exact(method, field):
    """Symplectic adjoint == jax.grad through the discrete solver, ~1e-12."""
    f = {"linear": linear_field, "mlp": mlp_field}[field]
    key = jax.random.PRNGKey(0)
    params = make_params(key)
    x0 = jax.random.normal(jax.random.PRNGKey(1), (5,))

    def loss(x0, params, mode):
        y = odeint(f, x0, params, t0=0.0, t1=1.0, method=method,
                   grad_mode=mode, n_steps=7)
        return jnp.sum(jnp.sin(y) ** 2)

    g_ref = jax.grad(loss, argnums=(0, 1))(x0, params, "backprop")
    g_sym = jax.grad(loss, argnums=(0, 1))(x0, params, "symplectic")
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_sym)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-10, atol=1e-12)


@pytest.mark.parametrize("mode", ["remat_step", "remat_solve"])
def test_remat_modes_gradient_exact(mode):
    params = make_params(jax.random.PRNGKey(0))
    x0 = jax.random.normal(jax.random.PRNGKey(1), (5,))

    def loss(x0, params, m):
        y = odeint(mlp_field, x0, params, method="dopri5", grad_mode=m,
                   n_steps=5)
        return jnp.sum(y ** 2)

    g_ref = jax.grad(loss, argnums=(0, 1))(x0, params, "backprop")
    g_ck = jax.grad(loss, argnums=(0, 1))(x0, params, mode)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_ck)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-12, atol=1e-14)


def test_symplectic_gradient_pytree_state():
    """Pytree (tuple) states work end-to-end."""
    params = make_params(jax.random.PRNGKey(0))
    x0 = (jnp.asarray([1.0, 0.5]), jnp.asarray([0.0, -0.2]))

    def loss(x0, params, mode):
        y = odeint(pytree_field, x0, params, method="bosh3", grad_mode=mode,
                   n_steps=9)
        return jnp.sum(y[0] ** 2) + jnp.sum(y[1] ** 2)

    g_ref = jax.grad(loss, argnums=(0, 1))(x0, params, "backprop")
    g_sym = jax.grad(loss, argnums=(0, 1))(x0, params, "symplectic")
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_sym)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-10, atol=1e-12)


@pytest.mark.slow   # unrolled multi-N convergence study
def test_adjoint_gradient_inexact_but_converging():
    """Continuous adjoint error is nonzero at coarse N and shrinks with N —
    the motivation for the paper (Sec. 3)."""
    params = make_params(jax.random.PRNGKey(2))
    x0 = jax.random.normal(jax.random.PRNGKey(3), (5,))

    def loss(x0, params, mode, n):
        y = odeint(mlp_field, x0, params, method="rk4", grad_mode=mode,
                   n_steps=n)
        return jnp.sum(y ** 2)

    errs = []
    for n in (4, 16):   # 4x refinement is enough to see the O(h^p) decay
        g_ref = jax.grad(loss)(x0, params, "backprop", n)
        g_adj = jax.grad(loss)(x0, params, "adjoint", n)
        errs.append(float(jnp.linalg.norm(g_ref - g_adj)
                          / jnp.linalg.norm(g_ref)))
    assert errs[0] > 1e-9          # visibly inexact at coarse resolution
    assert errs[1] < errs[0] / 4   # converging with N
    # symplectic is exact at the SAME coarse N:
    g_sym = jax.grad(loss)(x0, params, "symplectic", 4)
    g_ref = jax.grad(loss)(x0, params, "backprop", 4)
    assert float(jnp.linalg.norm(g_ref - g_sym)
                 / jnp.linalg.norm(g_ref)) < 1e-12


# --- adaptive stepping -------------------------------------------------------

@pytest.mark.parametrize("method,rtol", [
    ("heun12", 1e-4), ("bosh3", 1e-6), ("dopri5", 1e-8),
    ("fehlberg45", 1e-8)])
def test_adaptive_solution_accuracy(method, rtol):
    # low-order methods need far looser tolerances to stay within a step
    # budget — the paper's Table 3 observation.
    params = {"lam": jnp.asarray(-2.0)}

    def f(x, t, p):
        return p["lam"] * x

    x0 = jnp.asarray([1.0])
    cfg = AdaptiveConfig(rtol=rtol, atol=rtol * 1e-2, max_steps=512,
                         initial_step=0.05)
    y, stats = odeint_with_stats(f, x0, params, method=method, adaptive=cfg)
    exact = float(np.exp(-2.0))
    np.testing.assert_allclose(float(y[0]), exact, rtol=max(100 * rtol, 1e-6))
    assert int(stats["n_steps"]) > 0


@pytest.mark.slow   # unrolled replay reference
def test_adaptive_symplectic_gradient_exact():
    """Adaptive forward + symplectic backward reproduces the exact gradient
    of the realized discrete map.  Reference: replay the recorded accepted
    step sequence {t_n, h_n} as a differentiable fixed-sequence solve
    (while_loop itself is not reverse-differentiable in JAX)."""
    from repro.core.rk import rk_solve_adaptive, rk_step
    from repro.core.tableau import get_tableau as _gt

    params = make_params(jax.random.PRNGKey(4))
    x0 = jax.random.normal(jax.random.PRNGKey(5), (5,))
    cfg = AdaptiveConfig(rtol=1e-6, atol=1e-8, max_steps=64,
                         initial_step=0.1)
    tab = _gt("dopri5")

    sol = rk_solve_adaptive(mlp_field, tab, x0, 0.0, 1.0, params, cfg)
    n_acc = int(sol.n_accepted)
    assert 0 < n_acc < cfg.max_steps
    ts = np.asarray(sol.ts)[:n_acc]
    hs = np.asarray(sol.hs)[:n_acc]

    def loss_replay(x0, params):
        x = x0
        for t, h in zip(ts, hs):  # differentiable unrolled replay
            x, _ = rk_step(mlp_field, tab, x, jnp.asarray(t),
                           jnp.asarray(h), params)
        return jnp.sum(jnp.tanh(x) ** 2)

    def loss_sym(x0, params):
        y = odeint(mlp_field, x0, params, method="dopri5",
                   grad_mode="symplectic", adaptive=cfg)
        return jnp.sum(jnp.tanh(y) ** 2)

    # the replay must land on the same terminal state
    y_adapt = odeint(mlp_field, x0, params, method="dopri5",
                     grad_mode="symplectic", adaptive=cfg)
    np.testing.assert_allclose(np.asarray(y_adapt),
                               np.asarray(_replay_state(ts, hs, tab, x0,
                                                        params)),
                               rtol=1e-12)

    g_ref = jax.grad(loss_replay, argnums=(0, 1))(x0, params)
    g_sym = jax.grad(loss_sym, argnums=(0, 1))(x0, params)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_sym)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-9, atol=1e-11)


def _replay_state(ts, hs, tab, x0, params):
    from repro.core.rk import rk_step
    x = x0
    for t, h in zip(ts, hs):
        x, _ = rk_step(mlp_field, tab, x, jnp.asarray(t), jnp.asarray(h),
                       params)
    return x


def test_adaptive_adjoint_runs():
    params = make_params(jax.random.PRNGKey(6))
    x0 = jax.random.normal(jax.random.PRNGKey(7), (5,))
    cfg = AdaptiveConfig(rtol=1e-6, atol=1e-8, max_steps=64,
                         initial_step=0.1)

    def loss(x0, params):
        y = odeint(mlp_field, x0, params, method="dopri5",
                   grad_mode="adjoint", adaptive=cfg)
        return jnp.sum(y ** 2)

    g = jax.grad(loss, argnums=(0, 1))(x0, params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.all(np.isfinite(np.asarray(leaf)))


# --- invariant conservation (Theorem 1/2) ------------------------------------

def test_bilinear_invariant_conserved():
    """lambda^T delta is conserved by the symplectic pair: the gradient
    computed "through" any intermediate step equals the end-to-end gradient.
    We check it via VJP-of-JVP consistency: <lambda_0, delta_0 v> must equal
    <lambda_N, delta_N v> = directional derivative of L."""
    params = make_params(jax.random.PRNGKey(8))
    x0 = jax.random.normal(jax.random.PRNGKey(9), (5,))
    v = jax.random.normal(jax.random.PRNGKey(10), (5,))

    def solve(x0, mode):
        return odeint(mlp_field, x0, params, method="dopri5", grad_mode=mode,
                      n_steps=6)

    def loss(x0, mode):
        return jnp.sum(jnp.cos(solve(x0, mode)))

    # directional derivative via forward-mode on the discrete solver
    _, dd = jax.jvp(lambda x: loss(x, "backprop"), (x0,), (v,))
    # <grad_from_symplectic, v>
    g = jax.grad(lambda x: loss(x, "symplectic"))(x0)
    np.testing.assert_allclose(float(g @ v), float(dd), rtol=1e-10)
