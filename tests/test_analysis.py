"""Tests for the repro.analysis static auditor.

Three layers:

  * rule unit tests on tiny SYNTHETIC jaxprs with known properties — a
    scan with a known stacked-output size, a planted
    ``convert_element_type`` demotion, an oversized closed-over constant —
    so each rule's trigger condition is pinned independently of the
    solver stack;
  * regression tests for the dtype findings the auditor's first sweep
    surfaced in real code (the f32 error norm in core/rk.py, the f32 time
    embedding in models/cnf.py, the f32 kernel accumulators): the traces
    must stay clean, and the f64 kernel path must now accumulate in f64;
  * end-to-end probes over every registered gradient strategy, including
    a fast memory-scaling check (the full Table-1 audit is the CI
    ``python -m repro.analysis --check`` lane).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.analysis import (BUDGET_PATH, Case, aval_bytes, budget_findings,
                            case_jaxprs, constant_findings, count_eqns, dce,
                            donation_findings, dtype_findings,
                            enumerate_cases, flatness_findings, iter_eqns,
                            peak_resident_bytes)
from repro.analysis.memory import _grad_peak_bytes
from repro.core.api import GRADIENT_REGISTRY

F64 = jnp.float64


# ---------------------------------------------------------------------------
# traversal on synthetic jaxprs
# ---------------------------------------------------------------------------

def test_count_eqns_flat():
    closed = jax.make_jaxpr(lambda x: x + 1.0)(jnp.zeros((2,), F64))
    assert count_eqns(closed.jaxpr) == 1


def test_count_eqns_includes_scan_body():
    def f(x):
        def body(c, _):
            return jnp.sin(c) * 2.0 + 1.0, None
        c, _ = jax.lax.scan(body, x, None, length=5)
        return c

    closed = jax.make_jaxpr(f)(jnp.zeros((2,), F64))
    top = len(closed.jaxpr.eqns)
    # the 3-eqn body is counted once (scan traces its body once), on top
    # of the top-level eqns
    assert count_eqns(closed.jaxpr) > top


def test_iter_eqns_loop_depth_and_path():
    def f(x):
        def body(c, _):
            return c * 2.0, None
        c, _ = jax.lax.scan(body, x, None, length=5)
        return c + 1.0

    closed = jax.make_jaxpr(f)(jnp.zeros((2,), F64))
    depths = {}
    for eqn, ctx in iter_eqns(closed.jaxpr):
        depths.setdefault(ctx.loop_depth, []).append((eqn.primitive.name,
                                                      ctx.path))
    assert 0 in depths and 1 in depths
    # every depth-1 eqn sits under the scan
    assert all(path and path[-1] == "scan" for _, path in depths[1])


def test_aval_bytes():
    closed = jax.make_jaxpr(lambda x: x)(jnp.zeros((3, 5), F64))
    assert aval_bytes(closed.jaxpr.invars[0].aval) == 3 * 5 * 8


# ---------------------------------------------------------------------------
# liveness accounting: known scan carry / stacked-output sizes
# ---------------------------------------------------------------------------

def _stacking_scan(n, d=128):
    """Stacks an (n, d) f64 trajectory: peak must include n*d*8 bytes."""
    def f(x):
        def body(c, _):
            c = c * 2.0
            return c, c
        _, ys = jax.lax.scan(body, x, None, length=n)
        return ys
    return jax.make_jaxpr(f)(jnp.zeros((d,), F64))


def _carry_only_scan(n, d=128):
    def f(x):
        def body(c, _):
            return c * 2.0, None
        c, _ = jax.lax.scan(body, x, None, length=n)
        return c
    return jax.make_jaxpr(f)(jnp.zeros((d,), F64))


def test_peak_includes_stacked_output():
    n, d = 16, 128
    peak = peak_resident_bytes(_stacking_scan(n, d).jaxpr)
    assert peak >= n * d * 8


def test_peak_scaling_stacked_vs_carry_only():
    grow_stack = (peak_resident_bytes(_stacking_scan(64).jaxpr)
                  / peak_resident_bytes(_stacking_scan(8).jaxpr))
    grow_carry = (peak_resident_bytes(_carry_only_scan(64).jaxpr)
                  / peak_resident_bytes(_carry_only_scan(8).jaxpr))
    assert grow_stack > 4.0          # ~8x modulo the fixed carry term
    assert grow_carry < 1.1          # flat: length never enters the peak


def test_dce_drops_unused_stacked_output():
    """rk_solve_fixed always stacks checkpoints; when a caller (the
    continuous adjoint's backward) only reads x_final, XLA drops the
    stacked buffer — the liveness model must too, or O(L) strategies look
    O(N L)."""
    n, d = 32, 256

    def f(x):
        def body(c, _):
            c = c * 2.0
            return c, c
        c, ys = jax.lax.scan(body, x, None, length=n)
        return c                       # ys is dead

    closed = jax.make_jaxpr(f)(jnp.zeros((d,), F64))
    raw = peak_resident_bytes(closed.jaxpr)
    pruned = peak_resident_bytes(dce(closed.jaxpr))
    assert raw >= n * d * 8            # the dead stack is counted raw...
    assert pruned < n * d * 8          # ...and gone after DCE


# ---------------------------------------------------------------------------
# dtype-discipline rule on planted casts
# ---------------------------------------------------------------------------

def test_dtype_demotion_in_loop_is_error():
    def f(x):
        def body(c, _):
            return (c.astype(jnp.float32) * 2).astype(F64), None
        c, _ = jax.lax.scan(body, x, None, length=4)
        return c

    closed = jax.make_jaxpr(f)(jnp.zeros((4,), F64))
    fs = dtype_findings(closed, "planted")
    errors = [f for f in fs if f.severity == "error"]
    warnings = [f for f in fs if f.severity == "warning"]
    assert len(errors) == 1 and "float64" in errors[0].message \
        and "float32" in errors[0].message
    # the cast back up (f32 -> f64, inside the loop, dst != f32) warns
    assert len(warnings) == 1


def test_dtype_demotion_at_top_level_is_error_too():
    closed = jax.make_jaxpr(
        lambda x: x.astype(jnp.float32))(jnp.zeros((4,), F64))
    fs = dtype_findings(closed, "planted")
    assert [f.severity for f in fs] == ["error"]
    assert "top level" in fs[0].message


def test_dtype_f32_accumulate_idiom_not_flagged():
    """bf16 state upcast to exactly f32 inside a loop is the deliberate
    kernel accumulation idiom (kernels/ref.py), not a finding."""
    def f(x):
        def body(c, _):
            return c, c.astype(jnp.float32)
        _, ys = jax.lax.scan(body, x, None, length=4)
        return ys

    closed = jax.make_jaxpr(f)(jnp.zeros((4,), jnp.bfloat16))
    assert dtype_findings(closed, "idiom") == []


def test_dtype_rule_reproduces_the_f32_error_norm_bug():
    """The bug class the first analyzer sweep found in core/rk.py's
    adaptive driver: an f64 solve whose accept/reject norm was computed
    through a hardcoded .astype(float32) inside the while loop."""
    def solve_like(x):
        def cond(s):
            x, i = s
            err = jnp.sqrt(jnp.mean((x / 2.0).astype(jnp.float32) ** 2))
            return (err.astype(x.dtype) < 1e3) & (i < 5)

        def body(s):
            x, i = s
            return x * 1.1, i + 1

        return jax.lax.while_loop(cond, body, (x, 0))[0]

    closed = jax.make_jaxpr(solve_like)(jnp.zeros((4,), F64))
    errors = [f for f in dtype_findings(closed, "pre-fix")
              if f.severity == "error"]
    assert errors, "the planted f32 norm demotion must be detected"
    assert any("while" in f.message for f in errors)


# ---------------------------------------------------------------------------
# hazard rules
# ---------------------------------------------------------------------------

def test_constant_rule_flags_oversized_closure():
    big = np.ones((1 << 18,), np.float32)          # exactly 1 MiB

    def f(x):
        return x + jnp.asarray(big)[0]

    closed = jax.make_jaxpr(f)(jnp.zeros((), jnp.float32))
    fs = constant_findings(closed, "big")
    assert len(fs) == 1 and fs[0].severity == "warning"
    assert "1.0 MiB" in fs[0].message


def test_constant_rule_ignores_small_closure():
    small = np.ones((8,), np.float32)
    closed = jax.make_jaxpr(
        lambda x: x + jnp.asarray(small)[0])(jnp.zeros((), jnp.float32))
    assert constant_findings(closed, "small") == []


def test_donation_rule_matches_state_update_shape():
    x = jnp.zeros((1 << 14,), F64)                 # 128 KiB state
    fs = donation_findings(jax.make_jaxpr(lambda x: x * 2.0)(x), "upd")
    assert len(fs) == 1 and fs[0].severity == "info"
    tiny = jnp.zeros((4,), F64)
    assert donation_findings(
        jax.make_jaxpr(lambda x: x * 2.0)(tiny), "tiny") == []


# ---------------------------------------------------------------------------
# budget ratchet + flatness
# ---------------------------------------------------------------------------

def test_budget_rule_ratchet():
    closed = jax.make_jaxpr(
        lambda x: jnp.sin(x) + 1.0)(jnp.zeros((2,), F64))
    n = count_eqns(closed.jaxpr)
    ok = budget_findings(closed, "c", {"c:value": n}, "value")
    assert ok == []
    over = budget_findings(closed, "c", {"c:value": n - 1}, "value")
    assert [f.severity for f in over] == ["error"]
    missing = budget_findings(closed, "c", {}, "value")
    assert [f.severity for f in missing] == ["error"]
    slack = budget_findings(closed, "c", {"c:value": 100 * n}, "value")
    assert [f.severity for f in slack] == ["info"]


def test_flatness_rule():
    assert flatness_findings("c", "value", 4, 100, 32, 105) == []
    bad = flatness_findings("c", "value", 4, 100, 32, 800)
    assert [f.severity for f in bad] == ["error"]
    assert "unrolling" in bad[0].message


def test_committed_budgets_cover_every_enumerated_case():
    """analysis_budgets.json must have exactly one entry per traced jaxpr
    of the current registry — a newly registered strategy or capability
    without a committed budget fails here before it fails in CI."""
    budgets = json.loads(BUDGET_PATH.read_text())
    expected = set()
    for case in enumerate_cases(("dopri5",)):
        expected.add(f"{case.key}:value")
        if case.differentiable:
            expected.add(f"{case.key}:grad")
    # plus the serve engine's audited advance entry point (report.py)
    expected.add("serve/engine/dopri5/advance:value")
    # plus the sharded-solve collective probes (report.py traces value AND
    # grad of each cell on a (1,)-mesh)
    from repro.analysis.cases import SHARDED_PROBE_CELLS
    for strategy, stepping_kind in SHARDED_PROBE_CELLS:
        key = f"parallel/{strategy}/dopri5/{stepping_kind}/t1/sharded"
        expected.add(f"{key}:value")
        expected.add(f"{key}:grad")
    assert set(budgets) == expected
    assert all(isinstance(v, int) and v > 0 for v in budgets.values())


# ---------------------------------------------------------------------------
# end-to-end over the registry
# ---------------------------------------------------------------------------

def test_enumerate_cases_covers_all_strategies():
    cases = enumerate_cases(("dopri5",))
    assert {c.strategy for c in cases} == set(GRADIENT_REGISTRY)
    keys = [c.key for c in cases]
    assert len(keys) == len(set(keys))
    # every strategy has the universal fixed/t1 reverse-differentiable cell
    for name in GRADIENT_REGISTRY:
        assert Case(name, "fixed", "t1", False) in cases


@pytest.mark.parametrize("strategy", sorted(GRADIENT_REGISTRY))
def test_strategy_fixed_grad_trace_is_dtype_clean(strategy):
    """The real solver stack, per strategy: tracing the reverse-mode
    jaxpr of a fixed-grid f64 solve must produce zero dtype findings
    (this is the regression fence for the error-norm / combine / kernel
    dtype fixes)."""
    jaxprs = case_jaxprs(Case(strategy, "fixed", "t1", False))
    for kind in ("value", "grad"):
        closed = jaxprs[kind]
        assert closed is not None
        assert dtype_findings(closed, f"{strategy}:{kind}") == []
        assert constant_findings(closed, f"{strategy}:{kind}") == []


@pytest.mark.parametrize("strategy", ["adjoint", "backprop", "symplectic"])
def test_adaptive_trace_is_dtype_clean(strategy):
    """The adaptive while-loop drivers — where the f32 error norm lived
    pre-fix — must trace clean under x64."""
    jaxprs = case_jaxprs(Case(strategy, "adaptive", "t1", False))
    for kind in ("value", "grad"):
        closed = jaxprs[kind]
        if closed is None:
            continue
        errors = [f for f in dtype_findings(closed, strategy)
                  if f.severity == "error"]
        assert errors == []


def test_cnf_forward_trace_is_dtype_clean_f64():
    """models/cnf.py regression: the concatsquash time embedding rides in
    the state dtype (pre-fix it hardcoded f32, demoting every gate/bias
    product of an f64 solve)."""
    from repro.models.cnf import CNFConfig, cnf_forward, init_cnf

    cfg = CNFConfig(dim=3, hidden=(8,), n_components=1, n_steps=2,
                    trace="exact", method="bosh3", grad_mode="backprop",
                    combine_backend="jnp")
    params = init_cnf(jax.random.PRNGKey(0), cfg, dtype=F64)
    u = jnp.zeros((2, 3), F64)
    eps = jnp.ones((2, 3), F64)
    closed = jax.make_jaxpr(lambda p: cnf_forward(p, u, eps, cfg))(params)
    errors = [f for f in dtype_findings(closed, "cnf")
              if f.severity == "error"]
    assert errors == []


def test_butcher_combine_accumulates_f64():
    """kernels regression: the stage combine must accumulate f64 states in
    f64 (pre-fix both the Pallas kernels and the jnp oracles hardcoded an
    f32 accumulator, quantizing every f64 step update to ~1e-8)."""
    from repro.kernels.ops import butcher_combine, butcher_combine_rows

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(257,)), F64)
    ks = jnp.asarray(rng.normal(size=(7, 257)), F64)
    coefs = jnp.asarray(rng.normal(size=(7,)), F64)
    h = jnp.asarray(0.01, F64)
    want = np.asarray(x, np.float64) + 0.01 * np.einsum(
        "s,sd->d", np.asarray(coefs, np.float64), np.asarray(ks, np.float64))
    for use_pallas in (False, True):
        got = butcher_combine(x, ks, coefs, h, use_pallas=use_pallas)
        assert got.dtype == np.float64
        np.testing.assert_allclose(np.asarray(got), want,
                                   rtol=1e-14, atol=1e-14)

    rows = jnp.asarray(rng.normal(size=(2, 7)), F64)
    scale = jnp.asarray([1.0, 0.0], F64)
    want_rows = (np.asarray(scale, np.float64)[:, None]
                 * np.asarray(x, np.float64)[None]
                 + 0.01 * np.einsum("ms,sd->md",
                                    np.asarray(rows, np.float64),
                                    np.asarray(ks, np.float64)))
    for use_pallas in (False, True):
        got = butcher_combine_rows(x, ks, rows, scale, h,
                                   use_pallas=use_pallas)
        assert got.dtype == np.float64
        np.testing.assert_allclose(np.asarray(got), want_rows,
                                   rtol=1e-14, atol=1e-14)


def test_memory_scaling_symplectic_flat_backprop_linear():
    """Fast end-to-end memory check on a thin probe net (the full-width
    Table-1 audit with both methods is the CI --check lane): symplectic's
    static peak stays flat as n_steps grows 8x while DirectBackprop's
    grows ~linearly, and symplectic sits strictly below it."""
    kw = dict(dim=4, hidden=32)
    sym = [_grad_peak_bytes("symplectic", "dopri5", n, **kw)
           for n in (8, 64)]
    bp = [_grad_peak_bytes("backprop", "dopri5", n, **kw) for n in (8, 64)]
    assert sym[1] / sym[0] < 1.5
    assert bp[1] / bp[0] > 3.0
    assert sym[1] < bp[1]


@pytest.mark.slow
def test_run_analysis_check_is_clean():
    """The exact CI gate: every enumerated dopri5 case traces, every rule
    runs against the committed budgets, and there are zero errors."""
    from repro.analysis import load_budgets, run_analysis

    budgets = load_budgets()
    assert budgets is not None
    report = run_analysis(budgets, methods=("dopri5",), run_memory=False)
    assert report.ok, "\n".join(str(f) for f in report.errors)
