"""CNF model-level tests: stacked component params + solve-state dtypes.

Covers the PR-3 fixes:
  * the augmented solve state carries ``delta_logp`` in the DATA dtype
    (previously hardcoded float32, silently mixing dtypes under x64 and
    degrading the adaptive error norm / exact-gradient checks);
  * component params are stacked (leading n_components axis) and the
    component loop is a lax.scan — the stacked layout must reproduce the
    sequential per-component composition exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import AdaptiveConfig, odeint
from repro.models.cnf import (CNFConfig, _aug_field_exact, cnf_flow_path,
                              cnf_forward, cnf_nll, init_cnf)

# The reference solves below go through the deprecated odeint shim on
# purpose (they pin the models — now on solve() — to the legacy surface).
pytestmark = pytest.mark.filterwarnings(
    "ignore:odeint-style entry point:DeprecationWarning")


def _data(key, n=5, dim=3, dtype=jnp.float64):
    ku, ke = jax.random.split(key)
    u = jax.random.normal(ku, (n, dim), dtype=dtype)
    eps = jax.random.normal(ke, (n, dim), dtype=dtype)
    return u, eps


def test_dlp_dtype_follows_data():
    cfg = CNFConfig(dim=3, hidden=(8,), n_components=2, n_steps=4,
                    trace="exact", method="bosh3")
    params = init_cnf(jax.random.PRNGKey(0), cfg)
    for dtype in (jnp.float64, jnp.float32):
        u, eps = _data(jax.random.PRNGKey(1), dtype=dtype)
        z, dlp = cnf_forward(params, u, eps, cfg)
        assert dlp.dtype == dtype, dlp.dtype
        xs, dlps = cnf_flow_path(params, u, eps, cfg, jnp.array([0.5, 1.0]))
        assert dlps.dtype == dtype, dlps.dtype


def test_stacked_components_match_sequential_reference():
    """The scanned stacked-component forward == composing per-component
    solves by hand (identical discrete map, to rounding)."""
    M = 3
    cfg = CNFConfig(dim=3, hidden=(8,), n_components=M, n_steps=4,
                    trace="exact", method="dopri5")
    params = init_cnf(jax.random.PRNGKey(2), cfg)
    u, eps = _data(jax.random.PRNGKey(3))

    z, dlp = cnf_forward(params, u, eps, cfg)

    x, dlp_ref = u, jnp.zeros(u.shape[0], dtype=u.dtype)
    for i in range(M):
        comp = jax.tree_util.tree_map(lambda l: l[i], params["components"])
        x, dlp_i, _ = odeint(_aug_field_exact,
                             (x, jnp.zeros_like(dlp_ref), eps), comp,
                             t0=0.0, t1=cfg.t1, method=cfg.method,
                             n_steps=cfg.n_steps)
        dlp_ref = dlp_ref + dlp_i
    np.testing.assert_allclose(np.asarray(z), np.asarray(x),
                               rtol=1e-12, atol=1e-14)
    np.testing.assert_allclose(np.asarray(dlp), np.asarray(dlp_ref),
                               rtol=1e-12, atol=1e-14)


def test_flow_path_endpoint_matches_forward():
    """With ts=[t1] the flow path runs the IDENTICAL discrete map as
    cnf_forward (one segment of n_steps per component): the endpoint state
    and cumulative dlp must agree to rounding.  Interior observation times
    change the grid, so multi-ts paths only agree at discretization order —
    checked loosely alongside the shape contract."""
    cfg = CNFConfig(dim=3, hidden=(8,), n_components=2, n_steps=4,
                    trace="exact", method="bosh3")
    params = init_cnf(jax.random.PRNGKey(4), cfg)
    u, eps = _data(jax.random.PRNGKey(5))

    z, dlp = cnf_forward(params, u, eps, cfg)
    xs1, dlps1 = cnf_flow_path(params, u, eps, cfg, jnp.array([cfg.t1]))
    assert xs1.shape == (2,) + u.shape
    np.testing.assert_allclose(np.asarray(xs1[-1]), np.asarray(z),
                               rtol=1e-12, atol=1e-14)
    np.testing.assert_allclose(np.asarray(dlps1[-1]), np.asarray(dlp),
                               rtol=1e-12, atol=1e-14)

    ts = jnp.array([0.25, 0.5, 1.0])
    xs, dlps = cnf_flow_path(params, u, eps, cfg, ts)
    assert xs.shape == (2 * 3,) + u.shape and dlps.shape == (2 * 3,) + \
        u.shape[:1]
    np.testing.assert_allclose(np.asarray(xs[-1]), np.asarray(z),
                               rtol=1e-3, atol=1e-4)


def test_nll_grad_matches_backprop_through_stack():
    """Symplectic gradient through the scanned component stack == plain
    backprop through the same stacked solves."""
    cfg_s = CNFConfig(dim=2, hidden=(6,), n_components=2, n_steps=3,
                      trace="exact", method="bosh3",
                      grad_mode="symplectic")
    cfg_b = CNFConfig(dim=2, hidden=(6,), n_components=2, n_steps=3,
                      trace="exact", method="bosh3", grad_mode="backprop")
    params = init_cnf(jax.random.PRNGKey(6), cfg_s, dtype=jnp.float64)
    u, eps = _data(jax.random.PRNGKey(7), dim=2)
    g_s = jax.grad(cnf_nll)(params, u, eps, cfg_s)
    g_b = jax.grad(cnf_nll)(params, u, eps, cfg_b)
    for a, b in zip(jax.tree_util.tree_leaves(g_s),
                    jax.tree_util.tree_leaves(g_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-9, atol=1e-11)


def test_adaptive_error_norm_sees_uniform_dtype():
    """Under x64 the adaptive solve state is uniformly f64: an f32 dlp
    previously capped the error-norm resolution of that leaf.  The check:
    the adaptive symplectic gradient matches backprop-through-replay at
    f64-grade tolerance (impossible if part of the state rides in f32)."""
    cfg = CNFConfig(dim=2, hidden=(6,), n_components=1, trace="exact",
                    method="dopri5", adaptive=True, rtol=1e-8, atol=1e-10,
                    max_steps=64)
    params = init_cnf(jax.random.PRNGKey(8), cfg)
    u, eps = _data(jax.random.PRNGKey(9), n=3, dim=2)
    z, dlp = cnf_forward(params, u, eps, cfg)
    assert z.dtype == jnp.float64 and dlp.dtype == jnp.float64
    assert bool(jnp.all(jnp.isfinite(z))) and \
        bool(jnp.all(jnp.isfinite(dlp)))
